"""Figure 13: mobile-GPU clusters (10x slower devices, desktop master)
at 32 and 128 nodes."""
from __future__ import annotations

import numpy as np

from repro.core.costmodel import paper_network
from repro.core.simulator import (
    ClusterSpec,
    PAPER_TABLE5_GPU,
    bandwidth_from_beta,
    fit_paper_row,
    speedup_curve,
)


def _mobile_spec(n_nodes: int, bw_scale: float = 1.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    fit = fit_paper_row(500, 1500, PAPER_TABLE5_GPU[(500, 1500)], device="gpu")
    cf = fit["comp_fraction"]
    conv_master = 1.0 - cf  # desktop GPU master, step normalised to 1
    speeds = np.clip(rng.normal(0.1, 0.02, size=n_nodes), 0.05, 0.15)
    times = conv_master / speeds
    times[0] = conv_master  # the master stays a desktop GPU (§5.4.1)
    return ClusterSpec(
        device_conv_times=list(times),
        master_comp_time=cf,
        bandwidth_mbps=bandwidth_from_beta(fit["beta"]) * bw_scale,
        layers=paper_network(500, 1500),
        batch=1024,
    )


def run():
    rows = []
    for n in (32, 128):
        for bw_scale, bw_name in ((0.2, "slow"), (1.0, "meas"), (5.0, "fast")):
            curve = speedup_curve(_mobile_spec(n, bw_scale))
            rows.append(
                (
                    f"fig13_mobile_n{n}_bw-{bw_name}",
                    0.0,
                    f"max_speedup={curve.max():.2f}x at n={int(curve.argmax())+1}",
                )
            )
    # §5.4.1: 32 mobile GPUs cannot reach desktop-cluster speedups; 128 help
    c32 = speedup_curve(_mobile_spec(32)).max()
    c128 = speedup_curve(_mobile_spec(128)).max()
    rows.append(
        ("fig13_32_vs_128", 0.0,
         f"max32={c32:.2f}x max128={c128:.2f}x (paper: 32 insufficient)")
    )
    return rows
