"""Figures 11/12: low/mid-range vs high-end device clusters at several
link bandwidths — the paper's finding that device quality barely moves
the max speedup while bandwidth dominates."""
from __future__ import annotations

import numpy as np

from repro.core.costmodel import paper_network
from repro.core.simulator import (
    PAPER_TABLE4_CPU,
    PAPER_TABLE5_GPU,
    bandwidth_from_beta,
    fit_paper_row,
    gaussian_cluster,
    speedup_curve,
)


def _fit(device):
    table = PAPER_TABLE4_CPU if device == "cpu" else PAPER_TABLE5_GPU
    return fit_paper_row(500, 1500, table[(500, 1500)], device=device)


def _spec(tier: str, device: str, bw_scale: float, seed=0):
    fit = _fit(device)
    cf = fit["comp_fraction"]
    lo, hi = (0.8, 2.0) if tier == "low" else (2.5, 5.0)
    conv = (1.0 - cf) / lo  # faster tier -> faster master too
    return gaussian_cluster(
        n_nodes=32, base_conv_time=conv, rel_speed_low=1.0,
        rel_speed_high=hi / lo,
        master_comp_time=cf * conv / (1 - cf),
        bandwidth_mbps=bandwidth_from_beta(fit["beta"]) * bw_scale,
        layers=paper_network(500, 1500), batch=1024, seed=seed,
    )


def run():
    rows = []
    for device, fig in (("cpu", "fig11"), ("gpu", "fig12")):
        for tier in ("low", "high"):
            for bw_scale, bw_name in ((0.2, "slow"), (1.0, "meas"), (5.0, "fast")):
                curve = speedup_curve(_spec(tier, device, bw_scale))
                rows.append(
                    (
                        f"{fig}_{device}_{tier}end_bw-{bw_name}",
                        0.0,
                        f"max_speedup={curve.max():.2f}x at n={int(curve.argmax())+1}",
                    )
                )
        # the paper's claim: low vs high end max speedups nearly equal
        lo = speedup_curve(_spec("low", device, 1.0)).max()
        hi = speedup_curve(_spec("high", device, 1.0)).max()
        rows.append(
            (
                f"{fig}_{device}_tier_gap",
                0.0,
                f"low={lo:.2f}x high={hi:.2f}x gap={abs(lo-hi)/lo:.1%} (paper: negligible)",
            )
        )
    return rows
