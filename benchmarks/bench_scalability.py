"""Figures 9/10: 32-node scalability simulations (Gaussian-performance
clusters), calibrated with the Table-4/5 comm scale.

Two comm models are reported per case:
* ``paper``  — Eq. 2 verbatim (inputs counted once): reproduces the
  paper's own conclusion ("scalable without performance loss,
  stabilises after ~8 nodes");
* ``physical`` — beyond-paper correction: Algorithm 1 writes the inputs
  to EVERY slave socket, so the input term scales with n_slaves; at the
  calibrated bandwidth this regresses past ~8 nodes — a limitation the
  paper's simulator hides.
"""
from __future__ import annotations

import numpy as np

from repro.core.costmodel import paper_network
from repro.core.simulator import (
    PAPER_COMP_FRACTION,
    PAPER_TABLE4_CPU,
    PAPER_TABLE5_GPU,
    bandwidth_from_beta,
    fit_paper_row,
    gaussian_cluster,
    simulate,
    speedup_curve,
)


def _cluster(c1, c2, batch, device, broadcast_inputs, n=32, seed=0):
    if device == "cpu":
        fit = fit_paper_row(c1, c2, PAPER_TABLE4_CPU[(c1, c2)], device="cpu")
        lo, hi = 0.8, 1.9
    else:
        fit = fit_paper_row(c1, c2, PAPER_TABLE5_GPU[(c1, c2)], device="gpu")
        lo, hi = 0.8, 1.85
    cf = fit["comp_fraction"]
    conv = 1.0 - cf  # single-device step normalised to 1
    return gaussian_cluster(
        n_nodes=n,
        base_conv_time=conv,
        rel_speed_low=lo,
        rel_speed_high=hi,
        master_comp_time=cf,
        bandwidth_mbps=bandwidth_from_beta(fit["beta"]),
        layers=paper_network(c1, c2),
        batch=batch,
        seed=seed,
        broadcast_inputs=broadcast_inputs,
    )


def run():
    rows = []
    cases = [
        ("fig9a_cpu_50:500_b64", 50, 500, 64, "cpu"),
        ("fig9b_cpu_500:1500_b1024", 500, 1500, 1024, "cpu"),
        ("fig10_gpu_500:1500_b1024", 500, 1500, 1024, "gpu"),
    ]
    for name, c1, c2, batch, device in cases:
        for mode, broadcast in (("paper", False), ("physical", True)):
            spec = _cluster(c1, c2, batch, device, broadcast)
            curve = speedup_curve(spec)
            for n in (2, 4, 8, 16, 32):
                p = simulate(spec, n)
                rows.append(
                    (
                        f"{name}_{mode}_n{n}",
                        p.total * 1e6,
                        f"speedup={curve[n-1]:.2f}x comm%={p.comm_time/p.total:.0%}",
                    )
                )
            rows.append(
                (
                    f"{name}_{mode}_saturation",
                    0.0,
                    f"gain_8to32={curve[31]/curve[7]:.3f}x"
                    + (" (paper: stabilises >8)" if mode == "paper"
                       else " (corrected: broadcast regresses)"),
                )
            )
    return rows
