"""Tables 4/5 + Figures 5/7: predicted vs reported best speedups per
(network x device-count), from the Eq.1 + Eq.2 model calibrated per row
(one comm-scale scalar; CPU comp fractions pinned at §5.3.1's values)."""
from __future__ import annotations

from repro.core.simulator import (
    PAPER_TABLE4_CPU,
    PAPER_TABLE5_GPU,
    fit_paper_row,
)


def run():
    rows = []
    for device, table in (("cpu", PAPER_TABLE4_CPU), ("gpu", PAPER_TABLE5_GPU)):
        for (c1, c2), reported in table.items():
            fit = fit_paper_row(c1, c2, reported, device=device)
            for n, (pred, rep) in enumerate(zip(fit["predicted"], reported), start=2):
                rows.append(
                    (
                        f"table{'4' if device == 'cpu' else '5'}_{device}_{c1}:{c2}_n{n}",
                        0.0,
                        f"pred={pred:.2f}x reported={rep:.2f}x"
                        f" relerr={abs(pred-rep)/rep:.1%}",
                    )
                )
    return rows
