"""Benchmark aggregator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only a,b] [--smoke] [--json OUT]

Prints ``name,us_per_call,derived`` CSV rows.  us_per_call is 0 for
model-predicted (simulator) rows; wall-clock rows come from the real
master/slave cluster and the data-parallel baseline on this host.

``--smoke`` asks each module that supports it (run(smoke=True)) for a
tiny-shape pass — the CI benchmark-smoke lane.  ``--json`` additionally
writes the rows as a JSON artifact (the ``BENCH_*.json`` perf
trajectory).  ``--trajectory OUT`` extracts just the DETERMINISTIC
trajectory rows (bench_master_slave.TRAJECTORY_ROWS: wire-byte ratios,
sim-backend gains and the tcp-transport overhead, comparable across
commits) — the CI bench-smoke lane writes them to ``BENCH_PR4.json`` at
the repo root.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time
import traceback

from benchmarks import (
    bench_batchsize,
    bench_breakdown,
    bench_data_parallel,
    bench_device_range,
    bench_kernels,
    bench_master_slave,
    bench_mobile,
    bench_scalability,
    bench_speedup,
)

MODULES = {
    "speedup": bench_speedup,        # Tables 4/5, Figs 5/7 (node axis)
    "batchsize": bench_batchsize,    # Figs 5/7 (batch axis)
    "breakdown": bench_breakdown,    # Figs 6/8
    "scalability": bench_scalability,  # Figs 9/10
    "device_range": bench_device_range,  # Figs 11/12
    "mobile": bench_mobile,          # Fig 13
    "data_parallel": bench_data_parallel,  # Table 1 baseline
    "master_slave": bench_master_slave,  # Alg 1/2 real wall-clock + the
    #                                      pipelined full-train-step gain
    "kernels": bench_kernels,        # Pallas kernel rooflines + backends
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-shape pass where the module supports it")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write rows as a JSON artifact")
    ap.add_argument("--trajectory", default=None, metavar="OUT",
                    help="also write the deterministic trajectory rows "
                         "(TRAJECTORY_ROWS) as a JSON artifact, e.g. "
                         "BENCH_PR4.json")
    args = ap.parse_args()
    if args.only:
        names = args.only.split(",")
        unknown = [n for n in names if n not in MODULES]
        if unknown:
            raise SystemExit(f"unknown benchmark(s) {unknown}; choose from {list(MODULES)}")
        mods = {n: MODULES[n] for n in names}
    else:
        mods = MODULES

    print("name,us_per_call,derived")
    records = []
    failed = 0
    for name, mod in mods.items():
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        try:
            t0 = time.time()
            for row_name, us, derived in mod.run(**kwargs):
                print(f"{row_name},{us:.1f},{derived}")
                records.append(
                    {"bench": name, "name": row_name, "us_per_call": us,
                     "derived": derived}
                )
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failed += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke, "rows": records}, f, indent=2)
        print(f"# wrote {len(records)} rows to {args.json}", file=sys.stderr)
    if args.trajectory:
        wanted = set(bench_master_slave.TRAJECTORY_ROWS)
        traj = [r for r in records if r["name"] in wanted]
        missing = sorted(wanted - {r["name"] for r in traj})
        with open(args.trajectory, "w") as f:
            json.dump({"smoke": args.smoke, "rows": traj}, f, indent=2)
        print(f"# wrote {len(traj)} trajectory rows to {args.trajectory}"
              + (f" (missing: {missing})" if missing else ""),
              file=sys.stderr)
        if missing:
            failed += 1
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
