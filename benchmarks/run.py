"""Benchmark aggregator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only name]

Prints ``name,us_per_call,derived`` CSV rows.  us_per_call is 0 for
model-predicted (simulator) rows; wall-clock rows come from the real
master/slave cluster and the data-parallel baseline on this host.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    bench_batchsize,
    bench_breakdown,
    bench_data_parallel,
    bench_device_range,
    bench_kernels,
    bench_master_slave,
    bench_mobile,
    bench_scalability,
    bench_speedup,
)

MODULES = {
    "speedup": bench_speedup,        # Tables 4/5, Figs 5/7 (node axis)
    "batchsize": bench_batchsize,    # Figs 5/7 (batch axis)
    "breakdown": bench_breakdown,    # Figs 6/8
    "scalability": bench_scalability,  # Figs 9/10
    "device_range": bench_device_range,  # Figs 11/12
    "mobile": bench_mobile,          # Fig 13
    "data_parallel": bench_data_parallel,  # Table 1 baseline
    "master_slave": bench_master_slave,  # Alg 1/2 real wall-clock
    "kernels": bench_kernels,        # Pallas kernel rooflines
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(MODULES))
    args = ap.parse_args()
    mods = {args.only: MODULES[args.only]} if args.only else MODULES

    print("name,us_per_call,derived")
    failed = 0
    for name, mod in mods.items():
        try:
            t0 = time.time()
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}")
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failed += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
