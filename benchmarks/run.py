"""Benchmark aggregator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only a,b] [--smoke] [--json OUT]

Prints ``name,us_per_call,derived`` CSV rows.  us_per_call is 0 for
model-predicted (simulator) rows; wall-clock rows come from the real
master/slave cluster and the data-parallel baseline on this host.

``--smoke`` asks each module that supports it (run(smoke=True)) for a
tiny-shape pass — the CI benchmark-smoke lane.  ``--json`` additionally
writes the rows as a JSON artifact (the ``BENCH_*.json`` perf
trajectory).  ``--trajectory OUT`` extracts just the DETERMINISTIC
trajectory rows (the union of each selected module's TRAJECTORY_ROWS:
wire-byte ratios, sim-backend gains, transport/re-partition overheads,
the serving lane's req/s + tail latency, comparable across commits) —
the CI bench-smoke lane writes them to a ``BENCH_PR*.json`` at the
repo root.

``--check-against BASELINE`` is the bench-regression GATE: fresh rows
are compared to a committed ``BENCH_PR*.json`` and the run exits
non-zero if any higher-is-better gain row (the modules' GAIN_ROWS)
fell more than ``--regression-tolerance`` (default 20%) below its
baseline value — the CI bench-smoke lane fails instead of silently
shipping a perf regression.  Rows present only in one side are
reported but never gated (a new row has no baseline yet); comparing
ZERO rows is itself an error, so the gate cannot rot into a no-op.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time
import traceback

from benchmarks import (
    bench_batchsize,
    bench_breakdown,
    bench_data_parallel,
    bench_device_range,
    bench_hierarchy,
    bench_kernels,
    bench_master_slave,
    bench_mobile,
    bench_scalability,
    bench_serve,
    bench_speedup,
)

MODULES = {
    "speedup": bench_speedup,        # Tables 4/5, Figs 5/7 (node axis)
    "batchsize": bench_batchsize,    # Figs 5/7 (batch axis)
    "breakdown": bench_breakdown,    # Figs 6/8
    "scalability": bench_scalability,  # Figs 9/10
    "device_range": bench_device_range,  # Figs 11/12
    "mobile": bench_mobile,          # Fig 13
    "data_parallel": bench_data_parallel,  # Table 1 baseline
    "master_slave": bench_master_slave,  # Alg 1/2 real wall-clock + the
    #                                      pipelined full-train-step gain
    "kernels": bench_kernels,        # Pallas kernel rooflines + backends
    "serve": bench_serve,            # continuous-batching serving lane:
    #                                  req/s + tail latency over the cluster
    "hierarchy": bench_hierarchy,    # two-tier sub-master groups vs flat
    #                                  on a master-ingress-bound port
}


def _rows_attr(mods: dict, attr: str) -> tuple:
    """Union (order-preserving) of a row-name tuple (TRAJECTORY_ROWS /
    GAIN_ROWS) across the SELECTED modules — a --only subset never
    demands rows its modules cannot produce."""
    names = []
    for mod in mods.values():
        names.extend(getattr(mod, attr, ()))
    return tuple(dict.fromkeys(names))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-shape pass where the module supports it")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write rows as a JSON artifact")
    ap.add_argument("--trajectory", default=None, metavar="OUT",
                    help="also write the deterministic trajectory rows "
                         "(TRAJECTORY_ROWS) as a JSON artifact, e.g. "
                         "BENCH_PR5.json")
    ap.add_argument("--check-against", default=None, metavar="BASELINE",
                    help="bench-regression gate: compare fresh gain rows "
                         "(GAIN_ROWS) to this committed BENCH_PR*.json "
                         "and exit non-zero on any regression beyond "
                         "--regression-tolerance")
    ap.add_argument("--regression-tolerance", type=float, default=0.20,
                    help="allowed fractional drop of a gain row below "
                         "its baseline before the gate fails "
                         "(default 0.20 = 20%%)")
    args = ap.parse_args()
    if args.only:
        names = args.only.split(",")
        unknown = [n for n in names if n not in MODULES]
        if unknown:
            raise SystemExit(f"unknown benchmark(s) {unknown}; choose from {list(MODULES)}")
        mods = {n: MODULES[n] for n in names}
    else:
        mods = MODULES

    print("name,us_per_call,derived")
    records = []
    failed = 0
    for name, mod in mods.items():
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        try:
            t0 = time.time()
            for row_name, us, derived in mod.run(**kwargs):
                print(f"{row_name},{us:.1f},{derived}")
                records.append(
                    {"bench": name, "name": row_name, "us_per_call": us,
                     "derived": derived}
                )
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failed += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke, "rows": records}, f, indent=2)
        print(f"# wrote {len(records)} rows to {args.json}", file=sys.stderr)
    if args.trajectory:
        wanted = set(_rows_attr(mods, "TRAJECTORY_ROWS"))
        traj = [r for r in records if r["name"] in wanted]
        missing = sorted(wanted - {r["name"] for r in traj})
        with open(args.trajectory, "w") as f:
            json.dump({"smoke": args.smoke, "rows": traj}, f, indent=2)
        print(f"# wrote {len(traj)} trajectory rows to {args.trajectory}"
              + (f" (missing: {missing})" if missing else ""),
              file=sys.stderr)
        if missing:
            failed += 1
    if args.check_against:
        failed += check_against(
            records, args.check_against, args.regression_tolerance,
            gain_rows=_rows_attr(mods, "GAIN_ROWS"),
        )
    if failed:
        raise SystemExit(1)


def check_against(records, baseline_path: str, tolerance: float,
                  gain_rows=None) -> int:
    """The bench-regression gate: every gain row present in BOTH the
    fresh records and the committed baseline must satisfy
    ``fresh >= baseline * (1 - tolerance)``.  Returns the number of
    failures (regressions, or an empty comparison — a gate that
    compares nothing must not pass green)."""
    if gain_rows is None:
        gain_rows = _rows_attr(MODULES, "GAIN_ROWS")

    with open(baseline_path) as f:
        base_rows = {
            r["name"]: float(r["us_per_call"])
            for r in json.load(f)["rows"]
        }
    fresh_rows = {r["name"]: float(r["us_per_call"]) for r in records}
    compared = 0
    regressions = []
    for name in gain_rows:
        if name not in base_rows:
            print(f"# gate: {name} has no baseline yet (new row); skipped",
                  file=sys.stderr)
            continue
        if name not in fresh_rows:
            print(f"# gate: {name} missing from this run; skipped",
                  file=sys.stderr)
            continue
        compared += 1
        base, fresh = base_rows[name], fresh_rows[name]
        floor = base * (1.0 - tolerance)
        verdict = "REGRESSED" if fresh < floor else "ok"
        print(f"# gate: {name}: fresh={fresh:.3f} baseline={base:.3f} "
              f"floor={floor:.3f} -> {verdict}", file=sys.stderr)
        if fresh < floor:
            regressions.append(name)
    if compared == 0:
        print(f"# gate: compared ZERO gain rows against {baseline_path} — "
              f"refusing to pass an empty comparison", file=sys.stderr)
        return 1
    if regressions:
        print(f"# gate: FAILED — gain rows regressed >{tolerance:.0%} vs "
              f"{baseline_path}: {regressions}", file=sys.stderr)
        return 1
    print(f"# gate: {compared} gain rows within {tolerance:.0%} of "
          f"{baseline_path}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    main()
