"""REAL wall-clock benchmark of the paper's contribution on this host:
the master/slave distributed convolution over emulated heterogeneous
devices, comparing the Eq. 1 balanced allocation against the naive equal
split (§4.1.1's motivating example)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.master_slave import HeteroCluster


def _time_forward(cluster: HeteroCluster, x, w, reps=3) -> float:
    cluster.conv_forward(x, w)  # warm the per-shape jit caches
    t0 = time.perf_counter()
    for _ in range(reps):
        cluster.conv_forward(x, w)
    return (time.perf_counter() - t0) / reps


def run():
    rows = []
    rng = np.random.default_rng(0)
    x = rng.normal(size=(32, 32, 32, 3)).astype(np.float32)
    w = rng.normal(size=(5, 5, 3, 192)).astype(np.float32)

    # heterogeneous 3-device cluster: master + 1x slave + 3x-slow slave
    cluster = HeteroCluster([1.0, 1.0, 3.0])
    try:
        cluster.probe(image_size=32, in_channels=3, kernel_size=5,
                      num_kernels=64, batch=32)
        probe = list(cluster.probe_times)
        balanced = _time_forward(cluster, x, w)
        shares_bal = cluster.shares_for(w.shape[-1])

        # naive equal split (what the paper argues against)
        cluster.probe_times = [1.0, 1.0, 1.0]
        equal = _time_forward(cluster, x, w)

        rows.append(
            ("alg1_hetero_eq1_balanced", balanced * 1e6,
             f"shares={list(shares_bal)} probe={np.round(probe,3).tolist()}")
        )
        rows.append(
            ("alg1_hetero_equal_split", equal * 1e6,
             f"eq1_gain={equal/balanced:.2f}x (>1 means Eq.1 beats equal split)")
        )
    finally:
        cluster.shutdown()
    return rows
