"""REAL wall-clock benchmark of the paper's contribution on this host:
the master/slave distributed convolution over emulated heterogeneous
devices.  Four comparisons:

  1. Eq. 1 balanced allocation vs the naive equal split (§4.1.1's
     motivating example) on deterministic emulated devices,
  2. the async pipelined (double-buffered microbatch) protocol vs the
     per-layer barrier on a 2-conv-layer chain over finite emulated
     links — the comm/compute overlap the pipeline buys; the master's
     non-conv duty discounts its share via the comp-aware partitioner
     (measured, no longer pinned by hand),
  3. the FULL training step (forward + backward, ``conv_train_chain``)
     pipelined vs per-layer barrier calls — the ``trainstep_pipeline_gain``
     row, deterministic sim devices over finite links,
  4. real compute backends (numpy im2col vs jitted XLA) on the same
     cluster, the host's actual wall-clock,
  5. the wire itself: per-layer scatter+gather BYTES of kernel vs
     spatial partitioning (``comm_bytes_kernel_vs_spatial``), the fp16
     codec's byte reduction (``codec_gain``), the int8 absmax stage's
     ~4x cut (``int8_codec_bytes_gain``), the top-k sparsifier's
     per-gradient-slice cut (``topk_grad_bytes_gain``), and the
     train-step wall-clock of ``partition="auto"`` vs the paper's
     kernel axis under a 25 Mbps link
     (``auto_partition_trainstep_gain``) — all exact byte counts or
     deterministic sim compute,
  6. the transport seam: the SAME deterministic sim cluster driven over
     real localhost TCP subprocess slaves vs the in-process queue
     emulation (``tcp_vs_inproc_overhead``) — what serialization +
     kernel sockets + real process scheduling cost on top of the
     emulated wire — and the zero-copy shared-memory rings vs tcp on a
     wire-dominated co-located train step (``shm_vs_tcp_gain``), where
     skipping pickle + kernel socket copies is the whole point.

Rows 1-3 and 5-6 run the ``sim`` backend (deterministic sleep-for-flops
virtual devices), so the protocol effects are not drowned by host CPU
contention; row 4 is genuinely noisy host compute.  ``TRAJECTORY_ROWS``
names the rows the CI bench-smoke lane extracts into ``BENCH_PR4.json``,
the machine-readable perf trajectory.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.master_slave import HeteroCluster

SLOWDOWNS = [1.0, 1.5, 3.0]  # master + 1.5x slave + 3x-slow slave

# The deterministic rows the CI bench-smoke lane extracts into
# BENCH_PR*.json (benchmarks/run.py --trajectory): exact byte counts and
# sim-backend ratios, comparable across commits.
TRAJECTORY_ROWS = (
    "comm_bytes_kernel_vs_spatial",
    "codec_gain",
    "int8_codec_bytes_gain",
    "topk_grad_bytes_gain",
    "auto_partition_trainstep_gain",
    "batch_vs_kernel_fatlink_gain",
    "hybrid_auto_gain",
    "trainstep_pipeline_gain",
    "tcp_vs_inproc_overhead",
    "shm_vs_tcp_gain",
    "repartition_overhead",
)

# The higher-is-better subset the CI bench-regression gate
# (benchmarks/run.py --check-against) guards: a fresh run may not fall
# more than the gate's tolerance below the committed baseline on ANY of
# these.  Overhead rows (tcp_vs_inproc, repartition) trend the other way
# and are tracked, not gated.
GAIN_ROWS = (
    "comm_bytes_kernel_vs_spatial",
    "codec_gain",
    "int8_codec_bytes_gain",
    "topk_grad_bytes_gain",
    "auto_partition_trainstep_gain",
    "batch_vs_kernel_fatlink_gain",
    "hybrid_auto_gain",
    "trainstep_pipeline_gain",
    "shm_vs_tcp_gain",
)


def _relu_pool(y: np.ndarray) -> np.ndarray:
    """Master-only non-conv stage: ReLU + 2x2 max-pool (stride 2)."""
    y = np.maximum(y, 0.0)
    b, h, w, c = y.shape
    return y[:, : h // 2 * 2, : w // 2 * 2, :].reshape(
        b, h // 2, 2, w // 2, 2, c
    ).max(axis=(2, 4))


def _time_forward(cluster: HeteroCluster, x, w, reps=3) -> float:
    cluster.conv_forward(x, w)  # warm the per-shape jit caches
    t0 = time.perf_counter()
    for _ in range(reps):
        cluster.conv_forward(x, w)
    return (time.perf_counter() - t0) / reps


def _time_chain(cluster: HeteroCluster, x, weights, between, reps=3) -> float:
    cluster.conv_forward_chain(x, weights, between)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        cluster.conv_forward_chain(x, weights, between)
    return (time.perf_counter() - t0) / reps


# deterministic master-only stages for the train-step rows: sleep a fixed
# per-image time instead of computing, so barrier and pipelined schedules
# see identical non-conv work regardless of host noise
_STAGE_S_PER_IMAGE = 1.5e-3
_HEAD_S_PER_IMAGE = 1.0e-3


def _sim_stage(y):
    time.sleep(_STAGE_S_PER_IMAGE * y.shape[0])

    def vjp(g):
        time.sleep(_STAGE_S_PER_IMAGE * g.shape[0])
        return g

    return y, vjp


def _time_trainstep(cluster: HeteroCluster, x, weights, reps=3) -> float:
    def head(z, i):
        time.sleep(_HEAD_S_PER_IMAGE * z.shape[0])
        return 0.0, np.zeros_like(z)

    between = [_sim_stage] * len(weights)
    cluster.conv_train_chain(x, weights, between, head)  # warm (+ duty)
    # best-of-N: the stage sleeps and emulated-link delays are
    # deterministic, so the minimum is the schedule's true cost and
    # host scheduling spikes are discarded rather than averaged in
    best = float("inf")
    for _ in range(max(reps, 3)):
        t0 = time.perf_counter()
        cluster.conv_train_chain(x, weights, between, head)
        best = min(best, time.perf_counter() - t0)
    return best


def run(smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    batch = 8 if smoke else 32
    size = 16 if smoke else 32
    c1, c2 = (16, 32) if smoke else (64, 192)
    reps = 2 if smoke else 3
    micro = 4
    x = rng.normal(size=(batch, size, size, 3)).astype(np.float32)
    w = rng.normal(size=(5, 5, 3, c2)).astype(np.float32)
    w1 = rng.normal(size=(5, 5, 3, c1)).astype(np.float32)
    w2 = rng.normal(size=(5, 5, c1, c2)).astype(np.float32)
    weights, between = [w1, w2], [_relu_pool, _relu_pool]
    probe_kw = dict(image_size=size, in_channels=3, kernel_size=5,
                    num_kernels=max(8, c1 // 2), batch=batch)

    # -- 1. Eq. 1 balanced vs equal split (barrier, sim devices) ---------
    # Deterministic: device i runs at 1/slowdown the sim rate, so pinning
    # probe_times to the slowdowns IS the exact Eq. 1 input.
    cluster = HeteroCluster(SLOWDOWNS, ["sim"] * len(SLOWDOWNS))
    try:
        probe = list(cluster.probe(**probe_kw))
        cluster.probe_times = list(SLOWDOWNS)
        balanced = _time_forward(cluster, x, w, reps)
        shares_bal = cluster.shares_for(w.shape[-1])
        cluster.probe_times = [1.0] * len(SLOWDOWNS)  # naive equal split
        equal = _time_forward(cluster, x, w, reps)
        rows.append(
            ("alg1_hetero_eq1_balanced", balanced * 1e6,
             f"shares={[int(s) for s in shares_bal]} "
             f"probe={np.round(probe, 4).tolist()}")
        )
        rows.append(
            ("alg1_hetero_equal_split", equal * 1e6,
             f"eq1_gain={equal / balanced:.2f}x (>1 means Eq.1 beats equal split)")
        )
    finally:
        cluster.shutdown()

    # -- 2. barrier vs pipelined over finite links (sim devices) ---------
    # (a) one comm-heavy conv layer: the pipeline issues the next
    # microbatch's scatter while the current results are in flight,
    # hiding the link transfer time the barrier pays serially.
    xs = rng.normal(size=(16, 16, 16, 8)).astype(np.float32)
    ws1 = rng.normal(size=(5, 5, 8, 64)).astype(np.float32)
    ws2 = rng.normal(size=(5, 5, 64, 128)).astype(np.float32)
    results = {}
    for proto, pipeline in (("barrier", False), ("pipelined", True)):
        cluster = HeteroCluster(
            SLOWDOWNS, ["sim"] * len(SLOWDOWNS),
            pipeline=pipeline, microbatches=micro, bandwidth_mbps=50.0,
        )
        try:
            cluster.probe_times = list(SLOWDOWNS)  # exact Eq. 1 for sim
            results[proto] = _time_forward(cluster, xs, ws1, reps)
            timing = cluster.timing
        finally:
            cluster.shutdown()
        rows.append(
            (f"conv_sim_bw50_{proto}", results[proto] * 1e6,
             f"overlap_s={timing.overlap_s:.3f} wait_s={timing.gather_wait_s:.3f}")
        )
    gain = results["barrier"] / results["pipelined"]
    rows.append(
        ("conv_sim_bw50_pipeline_gain", gain,
         f"gain={gain:.2f}x (>1 means the async pipeline beats the "
         f"per-layer barrier; value is the ratio, not us)")
    )

    # (b) a 2-conv-layer chain with master-only ReLU+pool stages: the
    # comp-aware partitioner measures the master's non-conv duty on the
    # warm-up call and discounts its conv share automatically (this used
    # to be pinned by hand as an inflated probe entry); the pipeline
    # overlaps the between stages and the layer-boundary transfers with
    # the slaves' convolutions.
    results = {}
    for proto, pipeline in (("barrier", False), ("pipelined", True)):
        cluster = HeteroCluster(
            SLOWDOWNS, ["sim"] * len(SLOWDOWNS),
            pipeline=pipeline, microbatches=micro, bandwidth_mbps=50.0,
        )
        try:
            cluster.probe_times = list(SLOWDOWNS)  # exact Eq. 1 for sim
            results[proto] = _time_chain(
                cluster, xs, [ws1, ws2], [_relu_pool, _relu_pool], reps
            )
            timing = cluster.timing
            duty = cluster.comp_duty
        finally:
            cluster.shutdown()
        rows.append(
            (f"chain2_sim_bw50_{proto}", results[proto] * 1e6,
             f"overlap_s={timing.overlap_s:.3f} wait_s={timing.gather_wait_s:.3f} "
             f"comp_duty={duty:.2f}")
        )
    gain = results["barrier"] / results["pipelined"]
    rows.append(
        ("chain2_sim_bw50_pipeline_gain", gain,
         f"gain={gain:.2f}x (>1 means the async pipeline beats the "
         f"per-layer barrier; value is the ratio, not us)")
    )

    # -- 3. the FULL training step: fwd + bwd pipelined vs barrier -------
    # Deterministic sim devices over 50 Mbps links; the master-only
    # between stages and loss head sleep a fixed per-image time, so the
    # pipelined schedule can hide them (and the bwd transfers) behind
    # slave compute while the barrier pays everything serially.
    results = {}
    for proto, pipeline in (("barrier", False), ("pipelined", True)):
        cluster = HeteroCluster(
            SLOWDOWNS, ["sim"] * len(SLOWDOWNS),
            pipeline=pipeline, microbatches=micro, bandwidth_mbps=50.0,
        )
        try:
            cluster.probe_times = list(SLOWDOWNS)
            results[proto] = _time_trainstep(cluster, xs, [ws1, ws2], reps)
            timing = cluster.timing
        finally:
            cluster.shutdown()
        rows.append(
            (f"trainstep_sim_bw50_{proto}", results[proto] * 1e6,
             f"overlap_s={timing.overlap_s:.3f} wait_s={timing.gather_wait_s:.3f}")
        )
    gain = results["barrier"] / results["pipelined"]
    rows.append(
        ("trainstep_pipeline_gain", gain,
         f"gain={gain:.2f}x (>1 means pipelining the full fwd+bwd training "
         f"step beats per-layer barrier calls; value is the ratio, not us)")
    )

    # -- 5. the wire: spatial partitioning + the compact codec -----------
    # (a) EXACT per-layer scatter+gather bytes, kernel vs spatial, at 3
    # slaves (the ISSUE's acceptance shape: activation-dominated layer,
    # cin == cout).  One forward + one backward = one training layer.
    # Byte counters are deterministic: only shapes and Eq. 1 counts
    # (pinned probe times) enter.
    slow4 = [1.0, 1.5, 2.0, 3.0]  # master + 3 slaves
    bw, hw_, cw = (4, 16, 16) if smoke else (8, 32, 16)
    xw = rng.normal(size=(bw, hw_, hw_, cw)).astype(np.float32)
    ww = rng.normal(size=(3, 3, cw, cw)).astype(np.float32)
    gw = rng.normal(size=(bw, hw_, hw_, cw)).astype(np.float32)
    wire = {}
    for mode in ("kernel", "spatial"):
        cluster = HeteroCluster(slow4, ["sim"] * 4, partition=mode)
        try:
            cluster.probe_times = list(slow4)
            cluster.conv_forward(xw, ww)
            cluster.conv_backward(xw, ww, gw)
            wire[mode] = cluster.comm_bytes
        finally:
            cluster.shutdown()
    ratio = wire["kernel"] / wire["spatial"]
    rows.append(
        ("comm_bytes_kernel_vs_spatial", ratio,
         f"kernel={wire['kernel']}B spatial={wire['spatial']}B per "
         f"fwd+bwd layer at 3 slaves (>=2 means spatial cuts the wire "
         f"by the acceptance margin; value is the byte ratio, not us)")
    )

    # (b) the fp16 codec halves the bytes of the SAME traffic.
    wire_fp16 = {}
    for dtype in (None, "fp16"):
        cluster = HeteroCluster(slow4, ["sim"] * 4, wire_dtype=dtype)
        try:
            cluster.probe_times = list(slow4)
            cluster.conv_forward(xw, ww)
            cluster.conv_backward(xw, ww, gw)
            wire_fp16[dtype or "fp32"] = cluster.comm_bytes
        finally:
            cluster.shutdown()
    ratio = wire_fp16["fp32"] / wire_fp16["fp16"]
    rows.append(
        ("codec_gain", ratio,
         f"fp32={wire_fp16['fp32']}B fp16={wire_fp16['fp16']}B "
         f"(~2 means the codec halves the wire; ratio, not us)")
    )

    # (b2) the int8 stage quarters the SAME traffic (each float tensor
    # ships 1 B/element plus one 8 B scale).
    wire_int8 = {}
    for spec in (None, "int8"):
        cluster = HeteroCluster(slow4, ["sim"] * 4, wire_codec=spec)
        try:
            cluster.probe_times = list(slow4)
            cluster.conv_forward(xw, ww)
            cluster.conv_backward(xw, ww, gw)
            wire_int8[spec or "fp32"] = cluster.comm_bytes
        finally:
            cluster.shutdown()
    ratio = wire_int8["fp32"] / wire_int8["int8"]
    rows.append(
        ("int8_codec_bytes_gain", ratio,
         f"fp32={wire_int8['fp32']}B int8={wire_int8['int8']}B "
         f"(~4 means absmax int8 quarters the wire; ratio, not us)")
    )

    # (b3) top-k sparsified gradients: the GRADIENT-SLICE bytes of a
    # bwd message at topk:0.05 vs the dense fp32 slice (indices+values
    # = 8 B per surviving entry, so ~frac*8/4 of dense).  Codec-level
    # and exact — the grads class is the only slot topk touches.
    from repro.core.cluster import codec as codec_mod

    ck = codec_mod.WireCodec.from_spec("grads=topk:0.05")
    _, (_, _, enc_g) = ck.encode_down(("bwd", (xw, ww, gw)))
    dense_b = gw.nbytes
    sparse_b = codec_mod.wire_nbytes(enc_g)
    ratio = dense_b / sparse_b
    rows.append(
        ("topk_grad_bytes_gain", ratio,
         f"dense={dense_b}B topk:0.05={sparse_b}B per gradient slice "
         f"(~10 means only the largest 5% of entries ship at 8B each; "
         f"ratio, not us)")
    )

    # (c) wall-clock: the comm-aware auto axis vs the paper's kernel axis
    # on a 2-layer pipelined train step over 25 Mbps links (the paper's
    # regime is ~5 Mbps; 25 keeps the bench fast while comm still
    # dominates, so the pipeline cannot hide the kernel axis's full-x
    # broadcast).  Deterministic: sim compute is sleep-for-flops and the
    # probe is pinned to the exact sim times (flops/rate x slowdown),
    # which also calibrates the predictor's probe_flops scale.
    probe_flops = (
        2.0 * batch * size ** 2 * 25 * 3 * probe_kw["num_kernels"]
    )
    bc = 4 if smoke else 8
    xc = rng.normal(size=(bc, 32, 32, cw)).astype(np.float32)
    wwide1 = rng.normal(size=(3, 3, cw, cw)).astype(np.float32)
    wwide2 = rng.normal(size=(3, 3, cw, cw)).astype(np.float32)
    results = {}
    choices = {}
    for mode in ("kernel", "auto"):
        cluster = HeteroCluster(
            SLOWDOWNS, ["sim"] * len(SLOWDOWNS), partition=mode,
            pipeline=True, microbatches=micro, bandwidth_mbps=25.0,
        )
        try:
            cluster.probe_times = [sd * probe_flops / 1e9 for sd in SLOWDOWNS]
            cluster.probe_flops = probe_flops
            results[mode] = _time_trainstep(cluster, xc, [wwide1, wwide2], reps)
            choices[mode] = dict(cluster.partition_choices)
            timing = cluster.timing
        finally:
            cluster.shutdown()
        rows.append(
            (f"trainstep_sim_bw25_{mode}_axis", results[mode] * 1e6,
             f"overlap_s={timing.overlap_s:.3f} wait_s={timing.gather_wait_s:.3f} "
             f"picks={sorted(set(choices[mode].values())) or ['kernel']}")
        )
    gain = results["kernel"] / results["auto"]
    rows.append(
        ("auto_partition_trainstep_gain", gain,
         f"gain={gain:.2f}x (>1 means partition='auto' beats the paper's "
         f"kernel axis under a 25 Mbps link; ratio, not us)")
    )

    # (d) the THIRD axis on a FAT link: batch data parallelism vs the
    # paper's kernel axis at >= 1 Gbps.  Activation-heavy layers at a
    # real batch (the granularity sweet spot: one row per unit), sim
    # compute pinned fast (1e11 flops/s) so the emulated wire is what
    # the step measures, no master-stage sleeps — kernel re-broadcasts
    # the full x to every slave in BOTH sweeps while batch ships each
    # member only its rows; the replicated kernel is a ~24-byte
    # WeightRef after the warm step and the full-dW all-reduce is tiny
    # for 3x3x16x16.  Acceptance bar: >= 1.3x.
    bf = 16
    xf = rng.normal(size=(bf, 32, 32, cw)).astype(np.float32)
    wf1 = rng.normal(size=(3, 3, cw, cw)).astype(np.float32)
    wf2 = rng.normal(size=(3, 3, cw, cw)).astype(np.float32)
    probe_flops_f = 2.0 * bf * 32 ** 2 * 9 * cw * cw

    def _time_wirebound(mode, x, weights, probe_flops, bandwidth_mbps,
                        reps, choices=None):
        """Min wall-clock across reps AND across two fresh cluster
        instantiations: the emulated-link sleeps are deterministic, so
        the global minimum converges to the schedule's true cost while
        host scheduling spikes and unlucky thread placement (which vary
        per instantiation, not just per rep) are discarded."""
        def head(z, i):
            return 0.0, np.zeros_like(z)

        best = float("inf")
        for _ in range(2):
            cluster = HeteroCluster(
                SLOWDOWNS, ["sim:1e11"] * len(SLOWDOWNS), partition=mode,
                pipeline=True, microbatches=micro,
                bandwidth_mbps=bandwidth_mbps,
            )
            try:
                cluster.probe_times = [
                    sd * probe_flops / 1e11 for sd in SLOWDOWNS
                ]
                cluster.probe_flops = probe_flops
                cluster.conv_train_chain(x, weights, None, head)  # warm
                for _ in range(max(reps, 3)):
                    t0 = time.perf_counter()
                    cluster.conv_train_chain(x, weights, None, head)
                    best = min(best, time.perf_counter() - t0)
                if choices is not None:
                    choices.clear()
                    choices.extend(
                        sorted(set(cluster.partition_choices.values()))
                    )
            finally:
                cluster.shutdown()
        return best

    results = {}
    fat_choices = []
    for mode in ("kernel", "batch", "auto"):
        results[mode] = _time_wirebound(
            mode, xf, [wf1, wf2], probe_flops_f, 1000.0, reps,
            choices=fat_choices if mode == "auto" else None,
        )
    fat_gain = results["kernel"] / results["batch"]
    rows.append(
        ("batch_vs_kernel_fatlink_gain", fat_gain,
         f"gain={fat_gain:.2f}x (>1 means partition='batch' beats the "
         f"paper's kernel axis on a 1 Gbps link, activation-heavy "
         f"layers at batch {bf}; auto picked {fat_choices}; ratio, "
         f"not us)")
    )

    # (e) the HYBRID planner: one activation-heavy layer (batch-friendly
    # on this link) chained into one parameter-heavy layer (the
    # per-slave full-dW all-reduce sinks batch there; kernel keeps it),
    # 200 Mbps.  auto resolves the axis PER LAYER, so it must beat every
    # single-axis run — the per-layer picks are the point, not any one
    # axis.
    bh, ih = 8, 16
    xh = rng.normal(size=(bh, ih, ih, cw)).astype(np.float32)
    wh1 = rng.normal(size=(3, 3, cw, cw)).astype(np.float32)
    wh2 = rng.normal(size=(5, 5, cw, 256)).astype(np.float32)
    probe_flops_h = 2.0 * bh * ih ** 2 * 9 * cw * cw
    results = {}
    hyb_choices = []
    for mode in ("kernel", "spatial", "batch", "auto"):
        results[mode] = _time_wirebound(
            mode, xh, [wh1, wh2], probe_flops_h, 200.0, reps,
            choices=hyb_choices if mode == "auto" else None,
        )
    best_fixed = min(results[m] for m in ("kernel", "spatial", "batch"))
    hybrid_gain = best_fixed / results["auto"]
    rows.append(
        ("hybrid_auto_gain", hybrid_gain,
         f"gain={hybrid_gain:.2f}x (>1 means per-layer auto beats the "
         f"BEST single-axis run on a mixed act-heavy+param-heavy chain "
         f"at 200 Mbps; auto mixed {hyb_choices}; ratio, not us)")
    )

    # -- 6. the transport seam: real TCP subprocess slaves vs the -------
    # in-process queue emulation, SAME deterministic sim cluster and
    # workload (pipelined 2-layer forward chain).  The ratio is what the
    # real wire costs — pickle serialization, kernel socket hops, process
    # scheduling — relative to the emulation the repo benched until now.
    # Sim compute dominates by construction, so the ratio stays near 1
    # unless the transport regresses.
    results = {}
    for kind in ("inproc", "tcp"):
        cluster = HeteroCluster(
            SLOWDOWNS, ["sim"] * len(SLOWDOWNS),
            pipeline=True, microbatches=micro, transport=kind,
        )
        try:
            cluster.probe_times = list(SLOWDOWNS)  # exact Eq. 1 for sim
            results[kind] = _time_chain(
                cluster, xs, [ws1, ws2], [_relu_pool, _relu_pool], reps
            )
        finally:
            cluster.shutdown()
        rows.append(
            (f"chain2_sim_{kind}_transport", results[kind] * 1e6,
             "pipelined 2-layer chain, deterministic sim compute")
        )
    ratio = results["tcp"] / results["inproc"]
    rows.append(
        ("tcp_vs_inproc_overhead", ratio,
         f"tcp/inproc={ratio:.2f}x wall-clock on the same sim cluster "
         f"(~1 means the real wire adds little; ratio, not us)")
    )

    # -- 6b. zero-copy shm rings vs tcp on a WIRE-DOMINATED step ---------
    # Co-located 2-slave train step where the transport IS the cost:
    # ~17 MB activations through 1x1 kernels on fast sim devices, so tcp
    # pays pickle serialization + two kernel socket copies per hop while
    # shm writes each array once into the ring and copies it out once.
    # Deterministic compute (sim sleeps), real transport wall-clock.
    xb = rng.normal(size=(16, 128, 128, 16)).astype(np.float32)
    wb1 = rng.normal(size=(1, 1, 16, 16)).astype(np.float32)
    wb2 = rng.normal(size=(1, 1, 16, 16)).astype(np.float32)

    def _head_zero(z, i):
        return 0.0, np.zeros_like(z)

    results = {}
    for kind in ("tcp", "shm"):
        cluster = HeteroCluster(
            [1.0, 1.0, 1.0], ["sim:1e11"] * 3, transport=kind,
            pipeline=True, microbatches=micro,
        )
        try:
            cluster.probe_times = [1.0, 1.0, 1.0]
            cluster.conv_train_chain(
                xb, [wb1, wb2], [None, None], _head_zero)  # warm
            t0 = time.perf_counter()
            for _ in range(reps):
                cluster.conv_train_chain(
                    xb, [wb1, wb2], [None, None], _head_zero)
            results[kind] = (time.perf_counter() - t0) / reps
        finally:
            cluster.shutdown()
        rows.append(
            (f"trainstep_wirebound_{kind}", results[kind] * 1e6,
             "wire-dominated 2-slave train step, deterministic sim compute")
        )
    gain = results["tcp"] / results["shm"]
    rows.append(
        ("shm_vs_tcp_gain", gain,
         f"gain={gain:.2f}x (>=1.5 means the zero-copy shm rings beat tcp "
         f"on a wire-dominated co-located train step; ratio, not us)")
    )

    # -- 7. elasticity: one evict + admit + re-plan cycle ----------------
    # The control plane of the elastic runtime: retire a live slave,
    # admit a replacement (pinned probe time — no real probe runs), and
    # rebuild a train-step plan via the comm-aware Eq. 1 over the new
    # membership.  What a failure or a join costs BETWEEN steps, on top
    # of the recompute the step itself absorbs.
    cluster = HeteroCluster(SLOWDOWNS, ["sim"] * len(SLOWDOWNS))
    try:
        cluster.probe_times = list(SLOWDOWNS)
        cluster.plan_conv(xw.shape, ww, "train")  # warm the planner
        cycles = 3
        t0 = time.perf_counter()
        for _ in range(cycles):
            sd = cluster.slowdowns[-1]
            cluster.evict(cluster.slave_ids[-1])
            cluster.admit(slowdown=sd, backend="sim", probe_time=sd)
            cluster.plan_conv(xw.shape, ww, "train")
        dt = (time.perf_counter() - t0) / cycles
    finally:
        cluster.shutdown()
    rows.append(
        ("repartition_overhead", dt * 1e6,
         f"evict+admit+replan cycle on the inproc sim cluster, mean of "
         f"{cycles} (lower is better; us)")
    )

    # -- 4. real compute backends on this host (noisy, informational) ----
    for label, backends in (
        ("numpy", None),
        ("mixed_numpy_xla", ["numpy", "xla", "xla"]),
    ):
        cluster = HeteroCluster(SLOWDOWNS, backends,
                                pipeline=True, microbatches=micro)
        try:
            cluster.probe_times = list(SLOWDOWNS)
            dt = _time_chain(cluster, x, weights, between, reps)
        finally:
            cluster.shutdown()
        rows.append(
            (f"chain2_{label}_pipelined_host", dt * 1e6,
             "host wall-clock, real compute (contention-noisy)")
        )
    return rows
