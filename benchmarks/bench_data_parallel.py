"""Table 1: the data-parallel baseline (TensorFlow multi-GPU
cifar10_multi_gpu_train) the paper compares against.

We implement the baseline two ways:
1. REAL: synchronous data parallelism THROUGH the cluster substrate —
   ``HeteroCluster(partition="batch")`` drives the pipelined train
   chain over n emulated devices on a fat emulated link: each member
   computes gradients for its batch rows, the master sums the per-slave
   dW (the exact all-reduce).  Table 1's comparison now exercises the
   SAME scatter/gather/recovery machinery it is compared against,
   instead of a hand-rolled thread pool with its own split/average
   logic; and
2. MODEL: the step-time predictor with data-parallel communication
   (gradients of ALL parameters move every step, vs only the conv
   kernels for the paper's scheme), reproducing Table 1's shape: near-2x
   at 2 GPUs, saturating by 3-4 GPUs.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.master_slave import HeteroCluster

TABLE1 = {1: (0.35, 0.60), 2: (0.13, 0.20), 3: (0.13, 0.18), 4: (0.10, 0.10)}


def _model_rows():
    """Step-time model: compute scales 1/n; grad all-reduce is constant
    (parameter count), on a fast intra-node link."""
    rows = []
    params = (
        5 * 5 * 3 * 500 + 5 * 5 * 500 * 1500 + (8 * 8 * 1500) * 10
    )
    conv1, comp1 = 0.30, 0.10  # 1-GPU split of Table 1's ~0.4s step
    link_bytes_per_s = 8e9  # PCIe-class intra-node
    for n in range(1, 5):
        comm = 2 * params * 4 * (n - 1) / n / link_bytes_per_s if n > 1 else 0.0
        step = (conv1 + comp1) / n + comm
        mid = np.mean(TABLE1[n])
        rows.append(
            (
                f"table1_model_n{n}",
                step * 1e6,
                f"pred_step={step:.3f}s table1={TABLE1[n][0]:.2f}-{TABLE1[n][1]:.2f}s"
                f" pred_speedup={(conv1+comp1)/step:.2f}x"
                f" table1_speedup={np.mean(TABLE1[1])/mid:.2f}x",
            )
        )
    return rows


def _real_rows():
    """Measured synchronous data parallelism through the cluster itself:
    ``HeteroCluster(partition="batch")`` over n deterministic sim
    devices on a fat emulated link (intra-node class), driving the
    pipelined fwd+bwd train chain on a reduced two-conv network.
    Compute scales 1/n; the replicated-kernel broadcast and the
    per-slave full-dW return are the constant all-reduce cost that
    saturates Table 1's speedup curve."""
    rng = np.random.default_rng(0)
    batch = 16
    x = rng.normal(size=(batch, 16, 16, 3)).astype(np.float32)
    w1 = rng.normal(size=(5, 5, 3, 8)).astype(np.float32)
    w2 = rng.normal(size=(5, 5, 8, 16)).astype(np.float32)
    flops = 2.0 * batch * 16 * 16 * 25 * (3 * 8 + 8 * 16)
    rate = 2e9  # sim device speed (flops/s): step stays in the ms range

    rows = []
    base = None
    for n in (1, 2, 4):
        c = HeteroCluster(
            [1.0] * n, ["sim:2e9"] * n, partition="batch",
            pipeline=True, microbatches=2, bandwidth_mbps=8000.0,
        )
        try:
            c.probe_times = [flops / rate] * n
            c.probe_flops = flops

            def head(z, i):
                return None, np.zeros_like(z)

            c.conv_train_chain(x, [w1, w2], None, head)  # warm plans/caches
            reps = 2
            t0 = time.perf_counter()
            for _ in range(reps):
                c.conv_train_chain(x, [w1, w2], None, head)
            dt = (time.perf_counter() - t0) / reps
        finally:
            c.shutdown()
        base = base or dt
        rows.append(
            (
                f"table1_real_dataparallel_n{n}",
                dt * 1e6,
                f"speedup={base/dt:.2f}x over HeteroCluster(partition="
                f"'batch'), {n} sim device(s), 8 Gbps emulated link",
            )
        )
    return rows


def run():
    return _model_rows() + _real_rows()
