"""Table 1: the data-parallel baseline (TensorFlow multi-GPU
cifar10_multi_gpu_train) the paper compares against.

We implement the baseline two ways:
1. REAL: synchronous data parallelism over emulated devices (the batch is
   split across threads, each computes full-model gradients, the master
   averages) — built from the same HeteroCluster substrate, timed on this
   host with the small CNN; and
2. MODEL: the step-time predictor with data-parallel communication
   (gradients of ALL parameters move every step, vs only the conv
   kernels for the paper's scheme), reproducing Table 1's shape: near-2x
   at 2 GPUs, saturating by 3-4 GPUs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import paper_network
from repro.models.cnn import cnn_loss, init_cnn, make_cnn_config

TABLE1 = {1: (0.35, 0.60), 2: (0.13, 0.20), 3: (0.13, 0.18), 4: (0.10, 0.10)}


def _model_rows():
    """Step-time model: compute scales 1/n; grad all-reduce is constant
    (parameter count), on a fast intra-node link."""
    rows = []
    cfg = make_cnn_config(500, 1500)
    params = (
        5 * 5 * 3 * 500 + 5 * 5 * 500 * 1500 + (8 * 8 * 1500) * 10
    )
    conv1, comp1 = 0.30, 0.10  # 1-GPU split of Table 1's ~0.4s step
    link_bytes_per_s = 8e9  # PCIe-class intra-node
    for n in range(1, 5):
        comm = 2 * params * 4 * (n - 1) / n / link_bytes_per_s if n > 1 else 0.0
        step = (conv1 + comp1) / n + comm
        mid = np.mean(TABLE1[n])
        rows.append(
            (
                f"table1_model_n{n}",
                step * 1e6,
                f"pred_step={step:.3f}s table1={TABLE1[n][0]:.2f}-{TABLE1[n][1]:.2f}s"
                f" pred_speedup={(conv1+comp1)/step:.2f}x"
                f" table1_speedup={np.mean(TABLE1[1])/mid:.2f}x",
            )
        )
    return rows


def _real_rows():
    """Measured synchronous data parallelism on host threads (reduced CNN
    so the bench stays fast): per-replica grad + average."""
    import concurrent.futures as cf

    cfg = make_cnn_config(16, 32)
    params = init_cnn(jax.random.key(0), cfg)
    grad_fn = jax.jit(
        lambda p, x, y: jax.grad(lambda q: cnn_loss(q, x, y, cfg=cfg)[0])(p)
    )
    rng = np.random.default_rng(0)
    batch = 64
    x = jnp.asarray(rng.normal(size=(batch, 32, 32, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=batch))
    grad_fn(params, x[:8], y[:8])  # compile per shard shape

    rows = []
    base = None
    for n in (1, 2, 4):
        shard = batch // n
        grad_fn(params, x[:shard], y[:shard])
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            with cf.ThreadPoolExecutor(n) as ex:
                gs = list(
                    ex.map(
                        lambda i: grad_fn(
                            params, x[i * shard : (i + 1) * shard],
                            y[i * shard : (i + 1) * shard],
                        ),
                        range(n),
                    )
                )
            g = jax.tree.map(lambda *a: sum(a) / n, *gs)
            jax.block_until_ready(g)
        dt = (time.perf_counter() - t0) / reps
        base = base or dt
        rows.append(
            (
                f"table1_real_dataparallel_n{n}",
                dt * 1e6,
                f"speedup={base/dt:.2f}x (1-core host: expect ~1x; shape check only)",
            )
        )
    return rows


def run():
    return _model_rows() + _real_rows()
