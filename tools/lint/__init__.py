"""reprolint — the repo-specific static-analysis + concurrency suite.

The cluster grew from a single-master conv protocol into a threaded,
elastic, authenticated distributed system, and every safety property
it relies on was enforced only by convention.  This package turns
those conventions into machine-checked invariants:

    import-graph          slave entrypoint never reaches jax eagerly
    auth-before-unpickle  accept paths authenticate before pickle.loads
    clock-injection       cluster/serve time flows through the clock
    blocking-under-lock   no blocking call while holding a lock
    future-resolution     futures resolve on every path, incl. errors
    thread-hygiene        threads daemon-or-joined; no silent swallows
    docstrings            public cluster/serve API stays documented

Run the static suite with ``python -m tools.lint`` (``--explain``
prints each invariant's rationale); run tests under the runtime
lock-order sanitizer with ``python -m tools.lint.lockorder -- <pytest
args>``.  Waive a finding with an inline ``# reprolint:
allow=<checker> -- <reason>`` comment (the reason is mandatory); see
docs/development.md for the policy.
"""
from __future__ import annotations

from tools.lint.core import Violation, apply_waivers, parse_waivers

__all__ = ["Violation", "apply_waivers", "parse_waivers"]
