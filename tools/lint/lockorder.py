"""Runtime lock-order sanitizer: record every lock-acquisition order,
build the global lock-order graph, fail on cycles.

A deadlock needs two threads taking the same pair of locks in opposite
orders — and the cluster now has plenty of candidates: transport byte
counters, writer queues, the serve loop's stats lock, the request
queue's condition.  Rather than hoping the chaos lane happens to
interleave the fatal schedule, the sanitizer makes ORDER itself the
observable: ``install()`` wraps ``threading.Lock``/``RLock`` so every
acquisition records "held X while acquiring Y" edges keyed by lock
ALLOCATION SITE (file:line — the TSan convention: two queue mutexes
born at the same line are one node, so an AB/BA inversion between
instances is still a cycle).  Any cycle in the aggregated graph is a
potential deadlock, regardless of whether this run's timing ever
wedged.

Run a test lane under the sanitizer:

    python -m tools.lint.lockorder --report lockorder.json -- \
        -q tests/test_fault_tolerance.py tests/test_elastic.py

The report JSON carries the node table, every ordered edge, and the
detected cycles; exit status is pytest's, or 3 when the tests passed
but a lock-order cycle was detected.  Edges are recorded at acquire
ENTRY (before blocking), so a run that actually deadlocks still has
the inverted edge on record when the lane times out.

Limitations (by design, documented in docs/development.md): locks
created before ``install()`` are invisible; same-site self-edges are
ignored (two instances of one class locked in sequence); C-extension
internal locks are not wrapped.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
from collections import defaultdict
from typing import Dict, List, Optional, Set, Tuple

_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock


class LockOrderMonitor:
    """Aggregates per-thread acquisition order into a site-level graph.

    ``edges[a]`` is the set of sites acquired while a lock born at
    site ``a`` was held; ``cycles()`` returns every elementary cycle
    found by DFS over that graph (each one a potential deadlock)."""

    def __init__(self):
        self._mu = _REAL_LOCK()  # the monitor's own lock is never wrapped
        self.edges: Dict[str, Set[str]] = defaultdict(set)
        self.sites: Dict[str, int] = defaultdict(int)  # site -> locks born
        self.acquisitions = 0
        self._tls = threading.local()

    # -- per-thread held stack -----------------------------------------
    def _held(self) -> List[str]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def note_alloc(self, site: str) -> None:
        """Record one lock allocated at ``site``."""
        with self._mu:
            self.sites[site] += 1

    def note_acquire(self, site: str) -> None:
        """Record edges held-site -> ``site`` and push it; called at
        acquire ENTRY so a real deadlock still records its edge."""
        held = self._held()
        if held:
            with self._mu:
                self.acquisitions += 1
                for h in held:
                    if h != site:  # same-site self-edges: see module doc
                        self.edges[h].add(site)
        else:
            with self._mu:
                self.acquisitions += 1
        held.append(site)

    def note_release(self, site: str) -> None:
        """Pop the most recent acquisition of ``site`` for this thread."""
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] == site:
                del held[i]
                return

    def note_failed(self, site: str) -> None:
        """A non-blocking acquire returned False: undo the push."""
        self.note_release(site)

    # -- analysis ------------------------------------------------------
    def cycles(self) -> List[List[str]]:
        """Every elementary cycle in the site-level order graph."""
        with self._mu:
            graph = {a: sorted(bs) for a, bs in self.edges.items()}
        found: List[List[str]] = []
        seen_keys: Set[Tuple[str, ...]] = set()

        def dfs(start: str, node: str, path: List[str], onpath: Set[str]):
            for nxt in graph.get(node, ()):
                if nxt == start:
                    cyc = path[:]
                    key = tuple(sorted(cyc))
                    if key not in seen_keys:
                        seen_keys.add(key)
                        found.append(cyc)
                elif nxt not in onpath and nxt > start:
                    # only expand nodes > start: each cycle found once,
                    # rooted at its smallest node
                    onpath.add(nxt)
                    dfs(start, nxt, path + [nxt], onpath)
                    onpath.discard(nxt)

        for start in sorted(graph):
            dfs(start, start, [start], {start})
        return found

    def report(self) -> dict:
        """JSON-serializable summary: sites, edges, cycles, counters."""
        with self._mu:
            edges = sorted((a, b) for a, bs in self.edges.items() for b in bs)
            sites = dict(sorted(self.sites.items()))
            acq = self.acquisitions
        return {
            "locks_by_site": sites,
            "ordered_edges": edges,
            "cycles": self.cycles(),
            "nested_acquisitions": acq,
        }


def _alloc_site(depth: int = 2) -> str:
    f = sys._getframe(depth)
    return f"{f.f_code.co_filename}:{f.f_lineno}"


class _SanitizedLock:
    """A ``threading.Lock`` stand-in that reports to the monitor.

    Duck-types the full lock protocol (``acquire``/``release``/
    ``locked``/context manager), so ``queue.Queue`` mutexes and
    ``threading.Condition(lock)`` work unchanged — ``Condition.wait``
    releases through ``release()``, which keeps the held-stack honest."""

    def __init__(self, monitor: LockOrderMonitor, site: str):
        self._inner = _REAL_LOCK()
        self._monitor = monitor
        self._site = site
        monitor.note_alloc(site)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._monitor.note_acquire(self._site)
        ok = self._inner.acquire(blocking, timeout)
        if not ok:
            self._monitor.note_failed(self._site)
        return ok

    def release(self) -> None:
        self._inner.release()
        self._monitor.note_release(self._site)

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<SanitizedLock {self._site} {self._inner!r}>"


class _SanitizedRLock:
    """``threading.RLock`` stand-in: reentrant acquisitions are counted
    but only the FIRST records an edge (a lock cannot deadlock against
    itself by reentering).  Exposes ``_is_owned``/``_release_save``/
    ``_acquire_restore`` so ``threading.Condition`` wait semantics stay
    correct AND keep the monitor's held-stack in sync."""

    def __init__(self, monitor: LockOrderMonitor, site: str):
        self._inner = _REAL_RLOCK()
        self._monitor = monitor
        self._site = site
        self._tls = threading.local()
        monitor.note_alloc(site)

    def _depth(self) -> int:
        return getattr(self._tls, "depth", 0)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        first = self._depth() == 0
        if first:
            self._monitor.note_acquire(self._site)
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._tls.depth = self._depth() + 1
        elif first:
            self._monitor.note_failed(self._site)
        return ok

    def release(self) -> None:
        self._inner.release()
        self._tls.depth = self._depth() - 1
        if self._depth() == 0:
            self._monitor.note_release(self._site)

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    # Condition protocol (threading.Condition getattr-probes for these)
    def _is_owned(self) -> bool:
        return self._inner._is_owned()

    def _release_save(self):
        state = self._inner._release_save()
        depth, self._tls.depth = self._depth(), 0
        self._monitor.note_release(self._site)
        return (state, depth)

    def _acquire_restore(self, saved) -> None:
        state, depth = saved
        self._monitor.note_acquire(self._site)
        self._inner._acquire_restore(state)
        self._tls.depth = depth


_installed: Optional[LockOrderMonitor] = None


def install() -> LockOrderMonitor:
    """Patch ``threading.Lock``/``RLock`` with monitored wrappers and
    return the monitor.  Locks created BEFORE install are untouched.
    Idempotent: a second install returns the active monitor."""
    global _installed
    if _installed is not None:
        return _installed
    monitor = LockOrderMonitor()

    def make_lock():
        return _SanitizedLock(monitor, _alloc_site())

    def make_rlock():
        return _SanitizedRLock(monitor, _alloc_site())

    threading.Lock = make_lock
    threading.RLock = make_rlock
    _installed = monitor
    return monitor


def uninstall() -> None:
    """Restore the real lock factories (existing wrappers live on)."""
    global _installed
    threading.Lock = _REAL_LOCK
    threading.RLock = _REAL_RLOCK
    _installed = None


def main(argv: Optional[List[str]] = None) -> int:
    """Run pytest under the sanitizer — see module docstring.

    Everything after ``--`` is passed to pytest verbatim.  Exit code:
    pytest's when nonzero, else 3 when a lock-order cycle was found,
    else 0."""
    ap = argparse.ArgumentParser(
        prog="python -m tools.lint.lockorder",
        description="run pytest under the lock-order sanitizer",
    )
    ap.add_argument("--report", default=None, metavar="FILE",
                    help="write the JSON lock-order report here")
    ap.add_argument("pytest_args", nargs="*",
                    help="arguments after -- go to pytest")
    if argv is None:
        argv = sys.argv[1:]
    if "--" in argv:
        split = argv.index("--")
        own, rest = argv[:split], argv[split + 1:]
    else:
        own, rest = argv, []
    args = ap.parse_args(own)
    pytest_args = args.pytest_args + rest

    monitor = install()
    try:
        import pytest

        rc = pytest.main(pytest_args)
    finally:
        uninstall()
    rep = monitor.report()
    cycles = rep["cycles"]
    if args.report:
        with open(args.report, "w") as f:
            json.dump(rep, f, indent=2, sort_keys=True)
    print(
        f"# lockorder: {sum(rep['locks_by_site'].values())} locks at "
        f"{len(rep['locks_by_site'])} sites, "
        f"{len(rep['ordered_edges'])} ordered edges, "
        f"{len(cycles)} cycle(s) -> {'FAILED' if cycles else 'ok'}",
        file=sys.stderr,
    )
    for cyc in cycles:
        print("#   potential deadlock: " + " -> ".join(cyc + [cyc[0]]),
              file=sys.stderr)
    if int(rc) != 0:
        return int(rc)
    return 3 if cycles else 0


if __name__ == "__main__":
    sys.exit(main())
