"""``python -m tools.lint`` — run the reprolint suite (driver.py)."""
import sys

from tools.lint.driver import main

sys.exit(main())
