"""Shared plumbing for the reprolint checkers.

A checker is a module exposing ``NAME`` (the id violations and waivers
use), ``INVARIANT`` (the ``--explain`` text: what the rule is and why
the repo needs it), and ``run(repo) -> list[Violation]``.

Allowlisting is inline and per-checker: a violation is waived by a

    # reprolint: allow=<checker>[,<checker>...] -- <justification>

comment on the flagged line or the line directly above it.  The
justification is MANDATORY — a reasonless waiver suppresses nothing —
so every exemption in the tree documents why the invariant legally
does not apply at that site (see docs/development.md, allowlist
policy).
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Sequence, Tuple

WAIVER_RE = re.compile(
    r"#\s*reprolint:\s*allow=([A-Za-z0-9_,-]+)\s*(?:--+|—)\s*(.*)"
)


@dataclasses.dataclass(frozen=True)
class Violation:
    """One broken invariant at one source location."""

    checker: str
    path: str  # repo-relative, posix separators
    line: int
    message: str

    def __str__(self) -> str:
        """``path:line: [checker] message`` — the CI-greppable form."""
        return f"{self.path}:{self.line}: [{self.checker}] {self.message}"


def rel(path: Path, repo: Path) -> str:
    """``path`` relative to ``repo`` as a posix string (or absolute when
    outside the repo, e.g. a test fixture directory)."""
    try:
        return path.resolve().relative_to(repo.resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def iter_py(root: Path) -> List[Path]:
    """Every ``.py`` under ``root`` (sorted), skipping ``__pycache__``."""
    return sorted(
        p for p in root.rglob("*.py") if "__pycache__" not in p.parts
    )


def parse_waivers(text: str) -> Dict[int, List[Tuple[set, str]]]:
    """Map line -> [(checker names, justification)] for every
    ``# reprolint: allow=...`` comment, via the tokenizer (so waivers
    inside string literals are not misread as live)."""
    waivers: Dict[int, List[Tuple[set, str]]] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(text).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = WAIVER_RE.search(tok.string)
            if m is None:
                continue
            names = {n.strip() for n in m.group(1).split(",") if n.strip()}
            reason = m.group(2).strip()
            waivers.setdefault(tok.start[0], []).append((names, reason))
    except tokenize.TokenError:  # pragma: no cover - unparsable file
        pass
    return waivers


def apply_waivers(
    violations: Sequence[Violation], repo: Path
) -> Tuple[List[Violation], int]:
    """Drop violations covered by an inline waiver WITH a justification.

    A waiver on line L covers violations on L (trailing comment) and
    L+1 (own-line comment above the flagged statement).  Returns the
    surviving violations and the count waived."""
    survivors: List[Violation] = []
    cache: Dict[str, Dict[int, List[Tuple[set, str]]]] = {}
    waived = 0
    for v in violations:
        path = repo / v.path if not Path(v.path).is_absolute() else Path(v.path)
        if v.path not in cache:
            try:
                cache[v.path] = parse_waivers(path.read_text())
            except OSError:
                cache[v.path] = {}
        entries = cache[v.path].get(v.line, []) + cache[v.path].get(v.line - 1, [])
        if any(v.checker in names and reason for names, reason in entries):
            waived += 1
        else:
            survivors.append(v)
    return survivors, waived


def terminal_name(node) -> str:
    """The rightmost identifier of a ``Name``/``Attribute`` chain
    (``self._send_lock`` -> ``_send_lock``), or ``""``."""
    import ast

    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def func_defs(tree) -> Iterable:
    """Every (Async)FunctionDef in ``tree``, nested ones included."""
    import ast

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
