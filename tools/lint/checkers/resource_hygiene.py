"""resource-hygiene: every SharedMemory mapping must have a close()
path, and every created segment an unlink() path.

A ``multiprocessing.shared_memory.SharedMemory`` segment is a REAL
file in ``/dev/shm``: it outlives the process that made it, and a
64 MiB ring leaked per crashed test run fills the host's shm mount in
an afternoon.  The discipline the shm transport follows — the creator
owns ``unlink()``, every attacher at least ``close()``s its mapping,
both on a guaranteed (finally / close-method) path — is what this
checker keeps mechanical:

- any file that calls ``SharedMemory(...)`` must also call
  ``.close()`` somewhere (the detach path must exist), and
- any file that creates segments (``SharedMemory(create=True, ...)``)
  must also call ``.unlink()`` (the removal path must exist).

The check is deliberately file-coarse (like thread-hygiene's join
search): it cannot prove the path is reached on every branch, but it
guarantees nobody adds a new segment user with NO cleanup path at
all — the failure mode that actually happens.  A site where leaking
is correct (a probe that hands the segment to another owner) carries
an inline waiver with its reason.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List

from tools.lint.core import Violation, iter_py, rel, terminal_name

NAME = "resource-hygiene"
INVARIANT = __doc__

ROOTS = ("src/repro/core/cluster", "src/repro/serve", "src/repro/launch")


def check_source(path: Path, text: str, repo: Path) -> List[Violation]:
    """Violations for one file (see module docstring for the rules)."""
    tree = ast.parse(text, filename=str(path))
    out: List[Violation] = []
    called = {
        n.func.attr
        for n in ast.walk(tree)
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
    }
    for node in ast.walk(tree):
        if not (
            isinstance(node, ast.Call)
            and terminal_name(node.func) == "SharedMemory"
        ):
            continue
        creates = any(
            kw.arg == "create"
            and isinstance(kw.value, ast.Constant)
            and kw.value.value is True
            for kw in node.keywords
        )
        if "close" not in called:
            out.append(Violation(
                NAME, rel(path, repo), node.lineno,
                "SharedMemory mapped but this file never calls .close(): "
                "the mapping leaks — detach on a guaranteed path",
            ))
        if creates and "unlink" not in called:
            out.append(Violation(
                NAME, rel(path, repo), node.lineno,
                "SharedMemory(create=True) but this file never calls "
                ".unlink(): the segment outlives the process in /dev/shm "
                "— the creator owns removal",
            ))
    return out


def run(repo: Path) -> List[Violation]:
    """Gate shm segment cleanup paths across the wire + launch tree."""
    out: List[Violation] = []
    for root in ROOTS:
        for path in iter_py(repo / root):
            out.extend(check_source(path, path.read_text(), repo))
    return out
