"""The reprolint checker registry: one module per repo invariant."""
from __future__ import annotations

from tools.lint.checkers import (
    auth_unpickle,
    blocking_lock,
    clock_injection,
    docstrings,
    future_resolution,
    import_graph,
    resource_hygiene,
    thread_hygiene,
)

#: registry order = report order; names are what waivers reference
ALL_CHECKERS = (
    import_graph,
    auth_unpickle,
    clock_injection,
    blocking_lock,
    future_resolution,
    thread_hygiene,
    resource_hygiene,
    docstrings,
)
