"""docstrings: every public symbol of the cluster + serving API stays
documented.

This is ``tools/check_docstrings.py`` — the PR 6 docstring-coverage
gate — folded into the unified driver as its seventh checker.  The
original script keeps its own CLI (``python tools/check_docstrings.py``,
the invocation CI and ``tests/test_docstring_gate.py`` already use);
this module reuses its walker so the two can never disagree about
what "documented" means.

Why it exists: ``core/cluster`` and ``serve`` are the repo's public
machinery — the pieces the launch CLI, the benches, and external
operators program against — and an undocumented public symbol there
is an API nobody can use without reading the implementation.
"""
from __future__ import annotations

import sys
from pathlib import Path
from typing import List

from tools.lint.core import Violation, iter_py, rel

NAME = "docstrings"
INVARIANT = __doc__


def run(repo: Path) -> List[Violation]:
    """Walk the docstring gate's default roots through its own
    ``_missing_in_module`` walker."""
    from tools import check_docstrings as cd

    out: List[Violation] = []
    files = 0
    for root in cd.DEFAULT_ROOTS:
        rootp = repo / root
        if not rootp.exists():
            out.append(Violation(NAME, root, 1,
                                 "docstring-gate root missing — refusing to pass"))
            continue
        for path in iter_py(rootp):
            files += 1
            for lineno, name in cd._missing_in_module(path):
                out.append(Violation(
                    NAME, rel(path, repo), lineno,
                    f"undocumented public symbol: {name}",
                ))
    if files == 0 and not out:  # pragma: no cover - defensive, like the CLI
        print("docstring gate: matched ZERO files", file=sys.stderr)
        out.append(Violation(NAME, ".", 1,
                             "docstring gate matched zero files — refusing to pass"))
    return out
