"""clock-injection: cluster and serve code reads time through the
injectable clock, never the wall directly.

Deadlines, heartbeats, admission control, and autoscaling all hinge on
time, and their tests only stay fast and deterministic because the
clock is a constructor parameter (``RequestQueue(clock=...)``,
``AutoScaler(clock=...)``, ``HeteroCluster(clock=...)``,
``TCPTransport(clock=...)``).  A bare ``time.monotonic()`` /
``time.time()`` / ``time.sleep()`` call re-couples the logic to the
wall clock: the fake-clock tests silently stop covering that branch
and the only way to test a timeout becomes actually waiting it out.

The checker flags every CALL of ``time.monotonic``/``time.time``/
``time.sleep`` (through any import alias) in ``core/cluster`` and
``serve``.  Default-argument *references* (``clock: Callable =
time.monotonic``) are not calls and pass — that is the sanctioned
injection idiom.  ``time.perf_counter`` is exempt: it measures
durations for accounting, never gates behavior.  Legitimate wall
interactions — bandwidth/slowdown emulation, whose entire job is to
really sleep, and slave-subprocess code with no test seam — carry
inline waivers with justifications.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List

from tools.lint.core import Violation, iter_py, rel

NAME = "clock-injection"
INVARIANT = __doc__

ROOTS = ("src/repro/core/cluster", "src/repro/serve")

_FORBIDDEN = {"monotonic", "time", "sleep"}


def check_source(path: Path, text: str, repo: Path) -> List[Violation]:
    """Violations for one file (see module docstring for the rule)."""
    tree = ast.parse(text, filename=str(path))
    time_aliases = set()
    direct = {}  # local name -> time.* function it binds
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "time":
                    time_aliases.add(alias.asname or "time")
        elif isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                if alias.name in _FORBIDDEN:
                    direct[alias.asname or alias.name] = alias.name
    out: List[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        hit = None
        if (
            isinstance(func, ast.Attribute)
            and func.attr in _FORBIDDEN
            and isinstance(func.value, ast.Name)
            and func.value.id in time_aliases
        ):
            hit = f"time.{func.attr}"
        elif isinstance(func, ast.Name) and func.id in direct:
            hit = f"time.{direct[func.id]}"
        if hit:
            out.append(Violation(
                NAME, rel(path, repo), node.lineno,
                f"direct {hit}() call: route through the injectable clock "
                f"(self._clock / the clock parameter) so deadline and "
                f"timeout logic stays testable without real waiting",
            ))
    return out


def run(repo: Path) -> List[Violation]:
    """Gate ``core/cluster`` and ``serve`` against wall-clock calls."""
    out: List[Violation] = []
    for root in ROOTS:
        for path in iter_py(repo / root):
            out.extend(check_source(path, path.read_text(), repo))
    return out
