"""import-graph: the TCP slave entrypoint must never transitively
import jax at module level.

Slave subprocesses are spawned as ``python -m
repro.core.cluster.protocol`` — one per device, sometimes on hosts
with no accelerator stack at all — and the whole elastic design
assumes they come up in tens of milliseconds.  The PEP 562 lazy
``__init__`` scheme (``repro/lazy.py``) exists to guarantee that, but
until this checker nothing enforced it: one eager ``import jax`` added
anywhere on the entrypoint's module-level import chain would silently
cost every spawn seconds and break jax-less slave hosts.

The checker builds the static module-level import graph from the
entry module (imports inside function bodies are LAZY by definition
and excluded; ``if TYPE_CHECKING:`` blocks never execute and are
excluded; package ``__init__`` modules along every import path are
included, because importing a submodule executes them) and fails if
any forbidden top-level distribution is reachable, printing the chain
that reaches it.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from tools.lint.core import Violation, rel

NAME = "import-graph"
INVARIANT = __doc__

ENTRY = "repro.core.cluster.protocol"
FORBIDDEN = ("jax", "jaxlib")


def module_path(src: Path, modname: str) -> Optional[Path]:
    """The file implementing ``modname`` under ``src``: ``mod.py`` or a
    package's ``__init__.py``; None for namespace packages (no file
    executes) and external modules."""
    base = src.joinpath(*modname.split("."))
    if base.with_suffix(".py").is_file():
        return base.with_suffix(".py")
    if (base / "__init__.py").is_file():
        return base / "__init__.py"
    return None


def _is_type_checking(test: ast.expr) -> bool:
    name = test.attr if isinstance(test, ast.Attribute) else getattr(test, "id", "")
    return name == "TYPE_CHECKING"


def toplevel_imports(tree: ast.Module) -> List[Tuple[ast.stmt, int]]:
    """Import statements that execute at module import time: module
    body plus class bodies and top-level ``if``/``try``/``with`` blocks
    — but NOT function bodies (lazy) or TYPE_CHECKING guards (never
    executed)."""
    out: List[Tuple[ast.stmt, int]] = []

    def walk(stmts):
        for node in stmts:
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                out.append((node, node.lineno))
            elif isinstance(node, ast.If):
                if not _is_type_checking(node.test):
                    walk(node.body)
                walk(node.orelse)
            elif isinstance(node, ast.Try):
                walk(node.body)
                for h in node.handlers:
                    walk(h.body)
                walk(node.orelse)
                walk(node.finalbody)
            elif isinstance(node, (ast.With, ast.ClassDef)):
                walk(node.body)

    walk(tree.body)
    return out


def _deps_of(src: Path, modname: str) -> List[Tuple[str, int]]:
    """(imported module name, line) pairs for ``modname``'s module-level
    imports, relative imports resolved against its package."""
    path = module_path(src, modname)
    if path is None:
        return []
    tree = ast.parse(path.read_text(), filename=str(path))
    pkg_parts = modname.split(".")
    if path.name != "__init__.py":
        pkg_parts = pkg_parts[:-1]
    deps: List[Tuple[str, int]] = []
    for node, line in toplevel_imports(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                deps.append((alias.name, line))
        else:  # ImportFrom
            if node.level:
                base_parts = pkg_parts[: len(pkg_parts) - (node.level - 1)]
                base = ".".join(base_parts + ([node.module] if node.module else []))
            else:
                base = node.module or ""
            if base:
                deps.append((base, line))
            for alias in node.names:
                if alias.name == "*":
                    continue
                cand = f"{base}.{alias.name}" if base else alias.name
                # only a real submodule is an import edge; an attribute
                # pulled from the base module is covered by the base edge
                if module_path(src, cand) is not None:
                    deps.append((cand, line))
    return deps


def _expand(src: Path, dep: str) -> List[str]:
    """A dependency plus every ancestor package whose ``__init__``
    executes on the way to it."""
    parts = dep.split(".")
    out = []
    for i in range(1, len(parts) + 1):
        prefix = ".".join(parts[:i])
        if i == len(parts) or module_path(src, prefix) is not None:
            out.append(prefix)
    return out


def reachable_from(
    src: Path, entry: str
) -> Tuple[Dict[str, List[Tuple[str, int]]], Dict[str, Tuple[str, int]]]:
    """BFS the module-level import graph from ``entry``.

    Returns ``(externals, parent)``: ``externals`` maps each reachable
    internal module to its non-repo imports ``(name, line)``;
    ``parent`` maps each reached module to ``(importer, line)`` for
    chain reconstruction."""
    externals: Dict[str, List[Tuple[str, int]]] = {}
    parent: Dict[str, Tuple[str, int]] = {}
    queue = [entry]
    seen = {entry}
    while queue:
        mod = queue.pop(0)
        externals[mod] = []
        for dep, line in _deps_of(src, mod):
            internal = False
            for d in _expand(src, dep):
                if module_path(src, d) is not None:
                    internal = True
                    if d not in seen:
                        seen.add(d)
                        parent[d] = (mod, line)
                        queue.append(d)
            if not internal:
                externals[mod].append((dep.split(".")[0], line))
    return externals, parent


def chain_to(parent: Dict[str, Tuple[str, int]], mod: str, entry: str) -> str:
    """Human-readable import chain ``entry -> ... -> mod``."""
    hops = [mod]
    while mod != entry and mod in parent:
        mod = parent[mod][0]
        hops.append(mod)
    return " -> ".join(reversed(hops))


def check(
    src: Path, entry: str, forbidden: Sequence[str], repo: Path
) -> List[Violation]:
    """Violations for every forbidden top-level import reachable from
    ``entry`` at module import time."""
    if module_path(src, entry) is None:
        return [Violation(NAME, rel(src, repo), 1,
                          f"entry module {entry!r} not found — refusing to pass")]
    externals, parent = reachable_from(src, entry)
    out: List[Violation] = []
    for mod, ext in sorted(externals.items()):
        for name, line in ext:
            if name in forbidden:
                path = module_path(src, mod)
                out.append(Violation(
                    NAME, rel(path, repo), line,
                    f"module-level import of {name!r} is reachable from the "
                    f"slave entrypoint ({chain_to(parent, mod, entry)}): slave "
                    f"subprocesses must stay {'/'.join(forbidden)}-free — make "
                    f"it lazy (function-level or PEP 562, see repro/lazy.py)",
                ))
    return out


def run(repo: Path) -> List[Violation]:
    """Gate the repo: ``repro.core.cluster.protocol`` must not reach
    jax/jaxlib through module-level imports."""
    return check(repo / "src", ENTRY, FORBIDDEN, repo)
