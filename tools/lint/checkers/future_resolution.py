"""future-resolution: a future, once created, must be resolved on
every path — including the exception paths.

PR 6's review found exactly this bug class: a ``ServeFuture`` handed
to a client, then stranded forever because the serve loop thread died
on an exception path that never resolved it — the client blocks in
``result()`` until its own timeout, with no error to show.  The same
shape applies to ``Pending`` (an in-flight scatter): one dropped on
the floor desynchronizes the FIFO gather order for the whole link.

Two rules make the class un-reintroducible:

1. Any function used as a ``threading.Thread`` target that touches
   future/pipeline state (``ServeFuture``/``Pending``/``_resolve``/
   ``inflight``/``_chain``) must consist of bookkeeping plus ONE
   ``try`` whose handlers include a catch-all (bare ``except`` or
   ``except BaseException``) and which has a ``finally`` — the shape
   of ``ClusterServer._loop``, where the catch-all fails every
   in-flight future and the ``finally`` rejects the leftovers.  A
   statement that can raise OUTSIDE that try is a path where the
   thread dies with futures unresolved.

2. Every ``ServeFuture()`` / ``Pending(...)`` construction must be
   returned by its enclosing function (directly or via a name that is
   returned): constructing one and dropping it strands the consumer
   by definition.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import List, Optional

from tools.lint.core import Violation, func_defs, iter_py, rel, terminal_name

NAME = "future-resolution"
INVARIANT = __doc__

ROOTS = ("src/repro/serve", "src/repro/core/cluster")

_FUTUREISH = re.compile(r"ServeFuture|Pending|_resolve|inflight|_chain\b")
_CONSTRUCTORS = {"ServeFuture", "Pending"}


def _has_call(node: ast.stmt) -> bool:
    return any(isinstance(n, ast.Call) for n in ast.walk(node))


def _is_catchall_try(node: ast.stmt) -> bool:
    if not isinstance(node, ast.Try):
        return False
    catchall = any(
        h.type is None
        or terminal_name(h.type) in ("BaseException",)
        for h in node.handlers
    )
    return catchall and bool(node.finalbody)


def _thread_targets(tree: ast.Module) -> List[str]:
    """Terminal names of in-module ``threading.Thread(target=...)``
    callables."""
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and terminal_name(node.func) == "Thread":
            for kw in node.keywords:
                if kw.arg == "target":
                    out.append(terminal_name(kw.value))
    return out


def _check_loop_shape(fn, path: Path, repo: Path, out: List[Violation]) -> None:
    body = fn.body
    if body and isinstance(body[0], ast.Expr) \
            and isinstance(body[0].value, ast.Constant):
        body = body[1:]  # docstring
    for stmt in body:
        if _is_catchall_try(stmt):
            continue
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.Pass, ast.Import,
                             ast.ImportFrom)) and not _has_call(stmt):
            continue
        out.append(Violation(
            NAME, rel(path, repo), stmt.lineno,
            f"thread target {fn.name}() owns futures/pipeline state but "
            f"this statement is outside a catch-all try/finally: an "
            f"exception here kills the thread with futures unresolved "
            f"(the PR 6 stranded-ServeFuture bug class)",
        ))


def _returned_names(fn) -> set:
    names = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and isinstance(node.value, ast.Name):
            names.add(node.value.id)
    return names


def check_source(path: Path, text: str, repo: Path) -> List[Violation]:
    """Violations for one file (see module docstring for the rules)."""
    tree = ast.parse(text, filename=str(path))
    out: List[Violation] = []
    targets = set(_thread_targets(tree))
    for fn in func_defs(tree):
        src_seg = ast.get_source_segment(text, fn) or ""
        if fn.name in targets and _FUTUREISH.search(src_seg):
            _check_loop_shape(fn, path, repo, out)
        returned = _returned_names(fn)
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and terminal_name(node.func) in _CONSTRUCTORS):
                continue
            owner = _owner_stmt(fn, node)
            if owner is None:
                continue  # not a statement-level construction we track
            if isinstance(owner, ast.Return):
                continue
            if isinstance(owner, ast.Assign) and all(
                isinstance(t, ast.Name) and t.id in returned
                for t in owner.targets
            ):
                continue
            out.append(Violation(
                NAME, rel(path, repo), node.lineno,
                f"{terminal_name(node.func)} constructed here is neither "
                f"returned nor assigned to a returned name: an unreturned "
                f"future/pending is stranded by construction",
            ))
    return out


def _owner_stmt(fn, call: ast.Call) -> Optional[ast.stmt]:
    """The Return/Assign statement whose value IS ``call`` (not merely
    contains it), or None."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and node.value is call:
            return node
        if isinstance(node, ast.Assign) and node.value is call:
            return node
    return None


def run(repo: Path) -> List[Violation]:
    """Gate ``serve`` and ``core/cluster`` future/pending lifecycles."""
    out: List[Violation] = []
    for root in ROOTS:
        for path in iter_py(repo / root):
            out.extend(check_source(path, path.read_text(), repo))
    return out
