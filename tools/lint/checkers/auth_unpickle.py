"""auth-before-unpickle: accept/handshake paths must authenticate a
connection before unpickling anything it sent.

The cluster wire is pickle, so ``pickle.loads`` on attacker-supplied
bytes is arbitrary code execution in the master.  PR 4 introduced the
invariant: a freshly ``accept()``-ed connection must present the raw
per-cluster token — checked with ``hmac.compare_digest`` — before the
first frame is read, and a connection that fails is closed without
ever being unpickled.  An exposed listener (``listen_host="0.0.0.0"``)
makes this the repo's single most security-critical convention, and
it lived only in a docstring.

The checker finds every function that calls ``.accept(...)`` (a
handshake function) and requires that any DESERIALIZING call in it —
``pickle.loads`` or ``.read_on_master()`` — is preceded (by source
position) by a ``compare_digest`` call.  A raw ``.recv()`` is exempt:
it returns inert bytes, and reading the presented token is exactly how
authentication starts.  Line dominance is an
approximation of control-flow dominance, which is exactly right for
the straight-line handshake shape this repo uses; anything cleverer
belongs behind a waiver with a written justification.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List

from tools.lint.core import Violation, func_defs, iter_py, rel, terminal_name

NAME = "auth-before-unpickle"
INVARIANT = __doc__

ROOTS = ("src/repro/core/cluster",)

_UNPICKLING = {"read_on_master", "loads"}


def check_source(path: Path, text: str, repo: Path) -> List[Violation]:
    """Violations for one file (see module docstring for the rule)."""
    tree = ast.parse(text, filename=str(path))
    out: List[Violation] = []
    for fn in func_defs(tree):
        calls = [n for n in ast.walk(fn) if isinstance(n, ast.Call)]
        if not any(terminal_name(c.func) == "accept" for c in calls):
            continue
        digest_lines = [
            c.lineno for c in calls if terminal_name(c.func) == "compare_digest"
        ]
        first_digest = min(digest_lines) if digest_lines else None
        for c in calls:
            name = terminal_name(c.func)
            if name not in _UNPICKLING:
                continue
            # pickle.loads specifically, not any .loads
            if name == "loads" and isinstance(c.func, ast.Attribute):
                if terminal_name(c.func.value) not in ("pickle", "cPickle"):
                    continue
            if first_digest is None:
                out.append(Violation(
                    NAME, rel(path, repo), c.lineno,
                    f"{fn.name}() accepts connections and unpickles "
                    f"({name}) without any compare_digest auth check: the "
                    f"wire is pickle — authenticate before deserializing",
                ))
            elif c.lineno < first_digest:
                out.append(Violation(
                    NAME, rel(path, repo), c.lineno,
                    f"{fn.name}() unpickles ({name}, line {c.lineno}) "
                    f"BEFORE the compare_digest check (line {first_digest}): "
                    f"authenticate first",
                ))
    return out


def run(repo: Path) -> List[Violation]:
    """Gate every handshake path under ``core/cluster``."""
    out: List[Violation] = []
    for root in ROOTS:
        for path in iter_py(repo / root):
            out.extend(check_source(path, path.read_text(), repo))
    return out
