"""thread-hygiene: threads must be daemon-or-joined, and exceptions
must never be silently swallowed.

A non-daemon thread that nobody joins keeps the interpreter alive
after ``main`` returns — on a slave subprocess that means a zombie
holding the port; on the master it means a test suite that hangs at
exit (the reason ``protocol.main`` leaves via ``os._exit``).  Every
loop thread in the tree is therefore either ``daemon=True`` or joined
on a shutdown path, and this checker keeps it that way: a
``threading.Thread(...)`` without ``daemon=True`` is flagged unless a
``.join(`` on the receiving name appears in the same file.

Separately, a handler whose entire body is ``pass`` for a broad type
(bare ``except:``, ``except Exception:``, ``except BaseException:``)
erases errors the operator needed to see — a wedged cluster with an
empty log.  Narrow best-effort handlers (``except OSError: pass`` on
a double-close) are idiomatic and allowed; broad ones must either
record the error somewhere observable or carry a waiver saying why
silence is correct.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List

from tools.lint.core import Violation, iter_py, rel, terminal_name

NAME = "thread-hygiene"
INVARIANT = __doc__

ROOTS = ("src/repro/core/cluster", "src/repro/serve")

_BROAD = {"Exception", "BaseException"}


def check_source(path: Path, text: str, repo: Path) -> List[Violation]:
    """Violations for one file (see module docstring for the rules)."""
    tree = ast.parse(text, filename=str(path))
    out: List[Violation] = []
    joined = {
        terminal_name(n.func.value)
        for n in ast.walk(tree)
        if isinstance(n, ast.Call)
        and isinstance(n.func, ast.Attribute)
        and n.func.attr == "join"
    }
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and terminal_name(node.func) == "Thread":
            daemon = any(
                kw.arg == "daemon"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is True
                for kw in node.keywords
            )
            if daemon:
                continue
            # joined via the name it is assigned to?  (t = Thread(...);
            # ... t.join()) — same-file search, the shutdown-path idiom
            assigned = {
                terminal_name(t)
                for p in ast.walk(tree)
                if isinstance(p, ast.Assign) and p.value is node
                for t in p.targets
            }
            if assigned & joined:
                continue
            out.append(Violation(
                NAME, rel(path, repo), node.lineno,
                "Thread created without daemon=True and never joined in "
                "this file: it can outlive shutdown and hang interpreter "
                "exit — make it a daemon or join it on the shutdown path",
            ))
        elif isinstance(node, ast.ExceptHandler):
            body_is_pass = all(isinstance(s, ast.Pass) for s in node.body)
            broad = node.type is None or terminal_name(node.type) in _BROAD
            if body_is_pass and broad:
                what = "bare except" if node.type is None else \
                    f"except {terminal_name(node.type)}"
                out.append(Violation(
                    NAME, rel(path, repo), node.lineno,
                    f"{what}: pass swallows every error silently — record "
                    f"the failure somewhere observable, narrow the type, "
                    f"or waive with a reason why silence is correct here",
                ))
    return out


def run(repo: Path) -> List[Violation]:
    """Gate thread lifecycle + swallowed exceptions in cluster/serve."""
    out: List[Violation] = []
    for root in ROOTS:
        for path in iter_py(repo / root):
            out.extend(check_source(path, path.read_text(), repo))
    return out
