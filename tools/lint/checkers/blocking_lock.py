"""blocking-under-lock: no blocking call while holding a lock.

Every deadlock and latency cliff this codebase has flirted with starts
the same way: a thread takes a lock and then blocks on something whose
progress needs another thread — a socket send/recv, a blocking
``Queue.get``/``put``, a ``.join()``, a ``subprocess.wait()``.  The
convention (visible all over ``transport.py`` and ``server.py``) is
lock-for-bookkeeping-only: mutate the counter or the deque under the
lock, do the blocking work outside it.

The checker scans ``with <lock>:`` bodies (any context manager whose
name contains "lock") in the concurrency-bearing modules and flags
calls that can block: socket ``recv``/``sendall``/``accept``/
``select``, the framing helpers built on them (``_send_frame``/
``_recv_frame``/``_recv_exact``), ``.join``/``.wait``, blocking
``.get``/``.put`` on queue-shaped receivers, and ``read_on_master``/
``read_on_slave``.  The two deliberate exceptions in the tree — a
send lock that EXISTS to serialize whole frames onto a shared socket,
and a ``Condition.wait`` that releases its lock while blocked — carry
waivers explaining exactly that.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import List

from tools.lint.core import Violation, rel, terminal_name

NAME = "blocking-under-lock"
INVARIANT = __doc__

FILES = (
    "src/repro/core/cluster/transport.py",
    "src/repro/core/cluster/cluster.py",
    "src/repro/serve/server.py",
)

_LOCKISH = re.compile(r"lock", re.IGNORECASE)
_BLOCKING_ATTRS = {
    "recv", "sendall", "accept", "select", "join", "wait",
    "read_on_master", "read_on_slave",
}
_BLOCKING_FUNCS = {"_send_frame", "_recv_frame", "_recv_exact"}
_QUEUEISH = re.compile(
    r"(^|_)(q|wq|queue|stage|dest|items|to_slave|to_master)s?$"
)


def _is_nonblocking_qcall(call: ast.Call) -> bool:
    """``q.get(block=False)`` / ``q.put_nowait`` style calls are fine."""
    for kw in call.keywords:
        if kw.arg == "block" and isinstance(kw.value, ast.Constant) \
                and kw.value.value is False:
            return True
    return False


def _scan_body(stmts, path: Path, repo: Path, out: List[Violation]) -> None:
    for node in stmts:
        for sub in ast.walk(node):
            # nested defs run later, outside the lock
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue
            if not isinstance(sub, ast.Call):
                continue
            name = terminal_name(sub.func)
            blocked = None
            if name in _BLOCKING_ATTRS and isinstance(sub.func, ast.Attribute):
                blocked = f".{name}()"
            elif name in _BLOCKING_FUNCS and isinstance(sub.func, ast.Name):
                blocked = f"{name}()"
            elif (
                name in ("get", "put")
                and isinstance(sub.func, ast.Attribute)
                and _QUEUEISH.search(terminal_name(sub.func.value) or "")
                and not _is_nonblocking_qcall(sub)
            ):
                blocked = f"queue .{name}()"
            if blocked:
                out.append(Violation(
                    NAME, rel(path, repo), sub.lineno,
                    f"blocking call {blocked} inside a `with <lock>:` body: "
                    f"take the lock for bookkeeping only and block outside "
                    f"it (a blocked holder stalls every other thread)",
                ))


def check_source(path: Path, text: str, repo: Path) -> List[Violation]:
    """Violations for one file (see module docstring for the rule)."""
    tree = ast.parse(text, filename=str(path))
    out: List[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.With):
            continue
        if any(
            _LOCKISH.search(terminal_name(item.context_expr) or "")
            for item in node.items
        ):
            _scan_body(node.body, path, repo, out)
    return out


def run(repo: Path) -> List[Violation]:
    """Gate the concurrency-bearing transport/cluster/server modules."""
    out: List[Violation] = []
    for f in FILES:
        path = repo / f
        if path.is_file():
            out.extend(check_source(path, path.read_text(), repo))
    return out
