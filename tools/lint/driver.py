"""The ``python -m tools.lint`` driver: run every checker, apply the
inline waivers, report, and gate.

Exit status: 0 = every invariant holds (waivers included), 1 =
violations, 2 = usage error (unknown checker name).  ``--explain``
prints each checker's invariant and why the repo enforces it —
the text a developer staring at a red CI lane needs.
"""
from __future__ import annotations

import argparse
import sys
import textwrap
from pathlib import Path
from typing import List, Optional, Sequence

from tools.lint.checkers import ALL_CHECKERS
from tools.lint.core import Violation, apply_waivers


def repo_root() -> Path:
    """The repository root (two levels above this package)."""
    return Path(__file__).resolve().parent.parent.parent


def _select(only: Optional[str]):
    if only is None:
        return list(ALL_CHECKERS), None
    names = {n.strip() for n in only.split(",") if n.strip()}
    known = {c.NAME for c in ALL_CHECKERS}
    unknown = names - known
    if unknown:
        return None, (
            f"unknown checker(s) {sorted(unknown)}; known: {sorted(known)}"
        )
    return [c for c in ALL_CHECKERS if c.NAME in names], None


def explain(checkers) -> None:
    """Print every selected checker's invariant rationale."""
    for c in checkers:
        print(f"== {c.NAME} " + "=" * max(1, 66 - len(c.NAME)))
        print(textwrap.dedent(c.INVARIANT).strip())
        print()


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI entry — see module docstring."""
    ap = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="reprolint: repo-specific invariant checkers "
                    "(see docs/development.md)",
    )
    ap.add_argument("--only", default=None, metavar="NAME[,NAME...]",
                    help="run only these checkers")
    ap.add_argument("--explain", action="store_true",
                    help="print each checker's invariant and rationale, "
                         "then exit")
    ap.add_argument("--list", action="store_true", dest="list_checkers",
                    help="list checker names and exit")
    ap.add_argument("--root", default=None,
                    help="repo root to lint (default: this checkout)")
    args = ap.parse_args(argv)

    checkers, err = _select(args.only)
    if err:
        print(err, file=sys.stderr)
        return 2
    if args.list_checkers:
        for c in checkers:
            first = textwrap.dedent(c.INVARIANT).strip().splitlines()[0]
            print(f"{c.NAME:22s} {first}")
        return 0
    if args.explain:
        explain(checkers)
        return 0

    repo = Path(args.root).resolve() if args.root else repo_root()
    all_violations: List[Violation] = []
    summary = []
    total_waived = 0
    for c in checkers:
        found = c.run(repo)
        kept, waived = apply_waivers(found, repo)
        total_waived += waived
        all_violations.extend(kept)
        summary.append((c.NAME, len(kept), waived))
    for v in sorted(all_violations, key=lambda v: (v.path, v.line)):
        print(v)
    bad = len(all_violations)
    for name, kept, waived in summary:
        state = "FAILED" if kept else "ok"
        extra = f" ({waived} waived)" if waived else ""
        print(f"# {name}: {kept} violation(s){extra} -> {state}",
              file=sys.stderr)
    print(
        f"# reprolint: {len(checkers)} checkers, {bad} violation(s), "
        f"{total_waived} waived -> {'FAILED' if bad else 'ok'}",
        file=sys.stderr,
    )
    if bad:
        print("# run `python -m tools.lint --explain` for each invariant's "
              "rationale and the waiver policy", file=sys.stderr)
    return 1 if bad else 0


if __name__ == "__main__":  # pragma: no cover - exercised via __main__
    sys.exit(main())
