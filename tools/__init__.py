"""Repo tooling: the docstring-coverage gate (``check_docstrings.py``)
and the reprolint static-analysis + concurrency-sanitizer suite
(``tools/lint``, run as ``python -m tools.lint``)."""
