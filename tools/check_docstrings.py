#!/usr/bin/env python
"""Docstring-coverage gate for the public cluster + serving API.

Walks the given roots (default: ``src/repro/core/cluster`` and
``src/repro/serve``) and fails when any PUBLIC symbol — a module, a
module-level function or class, or a method of a public class whose
name does not start with ``_`` — lacks a docstring.  Dunder methods
are exempt except ``__init__`` on classes whose class docstring does
not document construction is NOT enforced separately: the class
docstring owns the constructor contract.

Pure stdlib (ast), no third-party linter needed:

    python tools/check_docstrings.py [ROOT ...]

Exit status 0 = fully documented, 1 = violations (one per line).
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

DEFAULT_ROOTS = ("src/repro/core/cluster", "src/repro/serve")

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


def _public(name: str) -> bool:
    return not name.startswith("_")


def _missing_in_module(path: Path) -> list:
    """Return ``(lineno, qualname)`` for every undocumented public
    symbol in one file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    missing = []
    if ast.get_docstring(tree) is None:
        missing.append((1, "<module>"))
    for node in tree.body:
        if isinstance(node, _FUNC_NODES) and _public(node.name):
            if ast.get_docstring(node) is None:
                missing.append((node.lineno, node.name))
        elif isinstance(node, ast.ClassDef) and _public(node.name):
            if ast.get_docstring(node) is None:
                missing.append((node.lineno, node.name))
            for sub in node.body:
                if (isinstance(sub, _FUNC_NODES) and _public(sub.name)
                        and ast.get_docstring(sub) is None):
                    missing.append((sub.lineno, f"{node.name}.{sub.name}"))
    return missing


def main(roots=None) -> int:
    """Check every ``.py`` under each root; print violations as
    ``path:line: symbol`` and return the violation count."""
    repo = Path(__file__).resolve().parent.parent
    roots = [Path(r) for r in (roots or DEFAULT_ROOTS)]
    count = 0
    files = 0
    for root in roots:
        root = root if root.is_absolute() else repo / root
        if not root.exists():
            print(f"docstring gate: missing root {root}", file=sys.stderr)
            return 1
        for path in sorted(root.rglob("*.py")):
            files += 1
            for lineno, name in _missing_in_module(path):
                try:
                    rel = path.relative_to(repo)
                except ValueError:   # explicit root outside the repo
                    rel = path
                print(f"{rel}:{lineno}: undocumented public symbol: {name}")
                count += 1
    if files == 0:
        print("docstring gate: matched ZERO files — refusing to pass",
              file=sys.stderr)
        return 1
    status = "FAILED" if count else "ok"
    print(f"# docstring gate: {files} files, {count} undocumented public "
          f"symbols -> {status}", file=sys.stderr)
    return count


if __name__ == "__main__":
    sys.exit(1 if main(sys.argv[1:] or None) else 0)
